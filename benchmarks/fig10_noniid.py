"""Fig. 10/11/12: accuracy under different Dirichlet distributions for
GenFV vs FL-only vs AIGC-only, across the three datasets.

One `repro.exp` sweep per dataset (scheme x alpha grid): cells share the
dataset builds, one FleetEngine per CNN shape, and one batched SUBP2-4
dispatch per round across all schemes/alphas of the dataset.

Paper claims validated (orderings/trends, DESIGN.md §2):
  * FL-only improves with alpha (less heterogeneity -> better);
  * GenFV >= FL-only, with the largest gap at small alpha;
  * AIGC-only converges fast but plateaus below GenFV.
cifar10 runs the fuller alpha sweep; cifar100/gtsrb run the endpoints.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl.rounds import RunConfig

SCHEMES = ("genfv", "fl_only", "aigc_only")


def run(rounds: int = 24) -> None:
    plan = {"cifar10": (0.1, 1.0), "cifar100": (0.1,), "gtsrb": (0.1,)}
    fl_cfg = GenFVConfig(batch_size=32, local_steps=8, num_vehicles=12)
    results = {}
    for dataset, alphas in plan.items():
        spec = ExperimentSpec(
            name=f"fig10_noniid_{dataset}",
            strategies=SCHEMES,
            alphas=alphas,
            base=RunConfig(dataset=dataset, rounds=rounds, train_size=2000,
                           test_size=160, width_mult=0.125, seed=5,
                           model_bits=11.2e6 * 32),
        )
        t0 = time.perf_counter()
        res = Sweep(spec, fl_cfg=fl_cfg).run()
        dt = (time.perf_counter() - t0) * 1e6 / (rounds * spec.n_cells)
        res.save()
        results[dataset] = res
        for alpha in alphas:
            for scheme in SCHEMES:
                acc = res.curve("accuracy", strategy=scheme, alpha=alpha)
                emit(f"fig10_noniid/{dataset}/alpha{alpha}/{scheme}", dt,
                     f"final_acc={acc[-1]:.3f} best={acc.max():.3f}")

    # trend summaries
    for dataset, alphas in plan.items():
        res = results[dataset]
        lo, hi = min(alphas), max(alphas)
        fl_lo = res.curve("accuracy", strategy="fl_only", alpha=lo)[-3:].mean()
        gv_lo = res.curve("accuracy", strategy="genfv", alpha=lo)[-3:].mean()
        ai = res.curve("accuracy", strategy="aigc_only", alpha=lo)
        aigc_plateau = np.mean(ai[-5:]) <= max(ai) + 0.02 and \
            np.mean(ai[-5:]) - np.mean(ai[len(ai) // 2:len(ai) // 2 + 5]) < 0.1
        claims = [f"genfv_matches_or_beats_fl_at_low_alpha={gv_lo >= fl_lo - 0.05}",
                  f"aigc_fast_start_then_plateau={aigc_plateau}"]
        if len(alphas) > 1:
            fl_hi = res.curve("accuracy", strategy="fl_only",
                              alpha=hi)[-3:].mean()
            claims.append(f"fl_improves_with_alpha={fl_hi >= fl_lo - 0.02}")
        emit(f"fig10_noniid/{dataset}/claims", 0.0, " ".join(claims))


if __name__ == "__main__":
    run()

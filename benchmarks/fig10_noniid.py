"""Fig. 10/11/12: accuracy under different Dirichlet distributions for
GenFV vs FL-only vs AIGC-only, across the three datasets.

Paper claims validated (orderings/trends, DESIGN.md §2):
  * FL-only improves with alpha (less heterogeneity -> better);
  * GenFV >= FL-only, with the largest gap at small alpha;
  * AIGC-only converges fast but plateaus below GenFV.
cifar10 runs the fuller alpha sweep; cifar100/gtsrb run the endpoints.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import ART, emit, ensure_art
from repro.configs.base import GenFVConfig
from repro.fl.rounds import GenFVRunner, RunConfig

SCHEMES = ("genfv", "fl_only", "aigc_only")


def one(dataset: str, alpha: float, scheme: str, rounds: int):
    fl_cfg = GenFVConfig(batch_size=32, local_steps=8, num_vehicles=12)
    r = GenFVRunner(RunConfig(dataset=dataset, alpha=alpha, rounds=rounds,
                              strategy=scheme, train_size=2000,
                              test_size=160, width_mult=0.125, seed=5,
                              model_bits=11.2e6 * 32), fl_cfg=fl_cfg)
    return r.train().curve("accuracy")


def run(rounds: int = 24) -> None:
    ensure_art()
    plan = {"cifar10": (0.1, 1.0), "cifar100": (0.1,), "gtsrb": (0.1,)}
    results = {}
    for dataset, alphas in plan.items():
        for alpha in alphas:
            for scheme in SCHEMES:
                t0 = time.perf_counter()
                acc = one(dataset, alpha, scheme, rounds)
                results[f"{dataset}/a{alpha}/{scheme}"] = acc.tolist()
                emit(f"fig10_noniid/{dataset}/alpha{alpha}/{scheme}",
                     (time.perf_counter() - t0) * 1e6 / rounds,
                     f"final_acc={acc[-1]:.3f} best={acc.max():.3f}")
    with open(f"{ART}/fig10_noniid.json", "w") as f:
        json.dump(results, f, indent=1)

    # trend summaries
    for dataset, alphas in plan.items():
        lo, hi = min(alphas), max(alphas)
        fl_lo = np.mean(results[f"{dataset}/a{lo}/fl_only"][-3:])
        gv_lo = np.mean(results[f"{dataset}/a{lo}/genfv"][-3:])
        ai = results[f"{dataset}/a{lo}/aigc_only"]
        aigc_plateau = np.mean(ai[-5:]) <= max(ai) + 0.02 and \
            np.mean(ai[-5:]) - np.mean(ai[len(ai) // 2:len(ai) // 2 + 5]) < 0.1
        claims = [f"genfv_matches_or_beats_fl_at_low_alpha={gv_lo >= fl_lo - 0.05}",
                  f"aigc_fast_start_then_plateau={aigc_plateau}"]
        if len(alphas) > 1:
            fl_hi = np.mean(results[f"{dataset}/a{hi}/fl_only"][-3:])
            claims.append(f"fl_improves_with_alpha={fl_hi >= fl_lo - 0.02}")
        emit(f"fig10_noniid/{dataset}/claims", 0.0, " ".join(claims))


if __name__ == "__main__":
    run()

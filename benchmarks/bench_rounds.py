"""Fleet-engine rounds/sec benchmark: vectorized one-dispatch engine
(fl/fleet.py) vs the sequential per-vehicle reference path.

Measures the fleet-execution portion of a GenFV round — h local-SGD steps
for all K selected vehicles plus the eq. (4) EMD-weighted aggregation — for
K in {4, 8, 16, 32}:

  sequential reference: K jitted `client_update` dispatches (each with its
      per-vehicle host sync) followed by `core/emd.py::aggregate`'s
      host-side leaf-by-leaf reduction (the seed implementation);
  vectorized engine:    ONE fused dispatch (vmapped local SGD + on-device
      stacked weighted reduction).

The default sweep uses an edge-scale CNN (width 0.0625, 8x8 inputs):
vehicular edge models are small, and that is the regime the engine targets —
round time dominated by per-vehicle dispatch + host aggregation overhead
rather than raw conv FLOPs. A paper-faithful 32x32 width-0.125 config is
also measured at K=16 (reported under "faithful") so the compute-bound end
of the spectrum stays visible; the ratio there is honest but smaller.

  PYTHONPATH=src python -m benchmarks.bench_rounds [--quick] [--out PATH]

Writes BENCH_rounds.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to 2 bucket sizes /
1 local step for the tier-1 smoke test (tests/test_fleet.py).
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List, Sequence

import jax
import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.genfv_cifar import cnn_config
from repro.core.emd import aggregate, data_weights, mean_emd
from repro.data.synthetic import make_image_dataset
from repro.fl.client import client_update
from repro.fl.fleet import FleetEngine, bucket_size
from repro.models.cnn import init_cnn

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_rounds.json")


def _time_rounds(fn, reps: int) -> float:
    """Best-of-reps wall time per round (min over reps; each rep is one
    full fleet round with fresh batch sampling, compile excluded)."""
    fn(np.random.default_rng(0))                      # warmup / compile
    best = float("inf")
    for r in range(1, reps + 1):
        with stopwatch() as sw:
            fn(np.random.default_rng(r))
        best = min(best, sw.elapsed_s)
    return best


def _bench_config(ks: Sequence[int], width: float, subsample: int, h: int,
                  batch: int, reps: int, n_data: int = 1024,
                  emd_bar: float = 0.5) -> List[Dict]:
    cfg = cnn_config("cifar10", width)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    aug = init_cnn(jax.random.PRNGKey(1), cfg)
    imgs, labels = make_image_dataset("cifar10", n_data, seed=0)
    imgs = imgs[:, ::subsample, ::subsample, :]

    rows = []
    for K in ks:
        datasets = [(imgs[i::K], labels[i::K]) for i in range(K)]
        rhos = data_weights([len(d[1]) for d in datasets])
        # donate=False: every rep restarts from the same params pytree, which
        # a donating dispatch would invalidate on accelerator backends
        engine = FleetEngine(cfg, h, batch, lr=5e-2, donate=False)

        def run_vectorized(rng):
            bi, bl = zip(*[engine.sample_batches(rng, di, dl)
                           for di, dl in datasets])
            new, _ = engine.run(params, list(bi), list(bl), rhos, emd_bar,
                                aug)
            jax.block_until_ready(new)

        def run_sequential(rng):
            models = []
            for di, dl in datasets:
                m, _ = client_update(params, cfg, di, dl, rng, h, batch,
                                     lr=5e-2)
                models.append(m)
            jax.block_until_ready(aggregate(models, rhos, aug, emd_bar))

        t_vec = _time_rounds(run_vectorized, reps)
        t_seq = _time_rounds(run_sequential, reps)
        rows.append({
            "K": K,
            "bucket": bucket_size(K),
            "t_vectorized_s": t_vec,
            "t_sequential_s": t_seq,
            "rounds_per_sec_vectorized": 1.0 / t_vec,
            "rounds_per_sec_sequential": 1.0 / t_seq,
            "speedup": t_seq / t_vec,
        })
        emit(f"rounds/K{K}_vectorized", t_vec * 1e6,
             f"speedup={t_seq / t_vec:.2f}x")
    return rows


def run_bench(quick: bool = False) -> Dict:
    if quick:
        sweep_cfg = dict(ks=(4, 8), width=0.0625, subsample=4, h=1, batch=2,
                         reps=2, n_data=256)
        faithful_cfg = None
    else:
        sweep_cfg = dict(ks=(4, 8, 16, 32), width=0.0625, subsample=4, h=2,
                         batch=4, reps=5)
        faithful_cfg = dict(ks=(16,), width=0.125, subsample=1, h=2, batch=8,
                            reps=3)

    results = _bench_config(**sweep_cfg)
    legacy: Dict = {"backend": jax.default_backend()}
    if faithful_cfg is not None:
        legacy["faithful_config"] = faithful_cfg
        legacy["faithful"] = _bench_config(**faithful_cfg)
    return record("fleet engine rounds/sec (vectorized vs sequential)",
                  quick=quick, config=sweep_cfg, results=results, **legacy)


def run(quick: bool = True) -> None:
    """benchmarks.run entry point: quick CSV-only sweep."""
    run_bench(quick=quick)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny widths, 2 buckets, 1 local step (smoke mode)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    with open(args.out, "w") as f:   # fail fast on an unwritable path,
        f.write("{}")                # not after minutes of benching
    print("name,us_per_call,derived")
    res = run_bench(quick=args.quick)
    write_json(res, args.out)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

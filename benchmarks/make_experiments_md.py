"""Regenerate the autogen tables of EXPERIMENTS.md: the §Sweeps /
§Theorem-1 sections from the versioned `repro.exp` artifacts
(artifacts/*.sweep.json, *.theorem1.json) and the §Dry-run / §Roofline
tables from the dry-run artifacts. The narrative sections are maintained
by hand; this script rewrites only the blocks between the AUTOGEN markers
(and creates a marker skeleton when EXPERIMENTS.md does not exist yet).

  PYTHONPATH=src python benchmarks/make_experiments_md.py
"""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "benchmarks", "artifacts")
ART_OPT = os.path.join(ROOT, "benchmarks", "artifacts_opt")
SWEEP_ART = os.path.join(ROOT, "artifacts")
MD = os.path.join(ROOT, "EXPERIMENTS.md")

SKELETON = """# EXPERIMENTS

Sweep results produced by the `repro.exp` experiment API (versioned
artifacts under `artifacts/`), plus roofline/dry-run tables where those
artifacts exist. Narrative is maintained by hand; the blocks between
AUTOGEN markers are rewritten by `benchmarks/make_experiments_md.py`.

## Sweeps

<!-- AUTOGEN:sweeps -->
<!-- /AUTOGEN:sweeps -->

## Theorem 1 — bound vs realized

<!-- AUTOGEN:theorem1 -->
<!-- /AUTOGEN:theorem1 -->

## Observability — per-phase timings

<!-- AUTOGEN:obs-timings -->
<!-- /AUTOGEN:obs-timings -->

## Streaming rounds — sustained rounds/hour under churn

<!-- AUTOGEN:streaming -->
<!-- /AUTOGEN:streaming -->

## Generation — AIGC dataplane

<!-- AUTOGEN:generation -->
<!-- /AUTOGEN:generation -->

## Roofline (single-pod)

<!-- AUTOGEN:roofline-sp -->
<!-- /AUTOGEN:roofline-sp -->

## Roofline (multi-pod)

<!-- AUTOGEN:roofline-mp -->
<!-- /AUTOGEN:roofline-mp -->

## Dry-run

<!-- AUTOGEN:dryrun -->
<!-- /AUTOGEN:dryrun -->

<!-- AUTOGEN:counts -->
<!-- /AUTOGEN:counts -->
"""


def load(d):
    out = {}
    for fn in sorted(glob.glob(os.path.join(d, "dryrun_*.json"))):
        r = json.load(open(fn))
        mesh = "2x16x16" if r.get("mesh", {}).get("pod") else "16x16"
        out[(r["arch"], r["shape"], mesh)] = r
    return out


def fmt(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def gib(b):
    return f"{b / 2**30:.1f}"


def roofline_table(recs, mesh="16x16", opt=None):
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "useful | HBM/dev (arg+temp) |" + (" opt: compute / temp / useful |" if opt else ""),
             "|---|---|---|---|---|---|---|---|" + ("---|" if opt else "")]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r.get("skipped"):
            lines.append(f"| {arch} | {shape} | SKIP — {r['note'][:60]} |||||||"
                         + ("|" if opt else ""))
            continue
        mem = r["memory"]
        extra = ""
        if opt:
            o = opt.get((arch, shape, m))
            if o and not o.get("skipped"):
                extra = (f" {fmt(o['compute_term_s'])} / "
                         f"{gib(o['memory'].get('temp_size_in_bytes', 0))}GiB / "
                         f"{o['useful_flops_ratio'] and round(o['useful_flops_ratio'], 2)} |")
            else:
                extra = " — |"
        lines.append(
            f"| {arch} | {shape} | {fmt(r['compute_term_s'])} "
            f"| {fmt(r['memory_term_s'])} | {fmt(r['collective_term_s'])} "
            f"| {r['dominant']} "
            f"| {r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 2)} "
            f"| {gib(mem.get('argument_size_in_bytes', 0))}+"
            f"{gib(mem.get('temp_size_in_bytes', 0))}GiB |" + extra)
    return "\n".join(lines)


def dryrun_summary(recs):
    lines = ["| arch | shape | mesh | compile | params | collective bytes "
             "(global) | by kind |", "|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if r.get("skipped"):
            continue
        kinds = ", ".join(f"{k.split('-')[-1]}={v / 2**30:.0f}G"
                          for k, v in sorted(r["collective_by_kind"].items())
                          if v > 2**30)
        lines.append(f"| {arch} | {shape} | {m} | {r['compile_s']:.0f}s "
                     f"| {r['params'] / 1e9:.2f}B "
                     f"| {r['collective_bytes_global'] / 2**30:.0f} GiB "
                     f"| {kinds} |")
    return "\n".join(lines)


def sweep_tables(directory: str = SWEEP_ART) -> str:
    """One summary row per cell of every *.sweep.json artifact, decoded
    through `SweepResult.load` (the single reader of the sweep/v1 layout)."""
    from repro.exp import SweepResult, list_artifacts
    paths = list_artifacts("sweep", directory)
    if not paths:
        return "_no sweep artifacts yet — run a `Sweep(...).save()`_"
    blocks = []
    for path in paths:
        res = SweepResult.load(path)
        meta = res.meta
        lines = [f"**{res.spec.name}** (`{os.path.basename(path)}`, "
                 f"{len(res.cells)} cells x {int(res.rounds.max())} rounds, "
                 f"{meta.get('planner_dispatches', '?')} batched planner "
                 f"dispatches, largest batch "
                 f"{meta.get('planner_largest_batch', '?')})",
                 "",
                 "| strategy | scenario | alpha | seed | final acc | "
                 "final loss | mean t_bar | dropped |",
                 "|---|---|---|---|---|---|---|---|"]
        final_acc = res.final("accuracy")
        final_loss = res.final("loss")
        for i, cell in enumerate(res.cells):
            T = int(res.rounds[i])
            if T == 0:
                continue
            lines.append(
                f"| {cell['strategy']} | {cell['scenario']} | "
                f"{cell['alpha']} | {cell['seed']} | "
                f"{final_acc[i]:.3f} | {final_loss[i]:.3f} | "
                f"{res.metrics['t_bar'][i, :T].mean():.2f}s | "
                f"{int(res.metrics['dropped'][i, :T].sum())} |")
        blocks.append("\n".join(lines))
    return "\n\n".join(blocks)


def obs_timing_tables(directory: str = SWEEP_ART) -> str:
    """Per-phase span timings from every `repro.obs/metrics/v1` artifact
    (*.metrics.json): one row per (span, compile/execute stage) with count,
    total and mean/min/max wall time. The compile rows separate
    trace-and-compile cost from steady-state execution."""
    from repro.obs import list_metrics_artifacts, load_metrics_artifact
    paths = list_metrics_artifacts(directory)
    if not paths:
        return ("_no metrics artifacts yet — run with an `Obs` tracer and "
                "`obs.save_metrics(name)`_")

    def ms(x):
        return f"{x * 1e3:.1f}"

    blocks = []
    for path in paths:
        doc = load_metrics_artifact(path)
        spans = [d for d in doc.get("dists", [])
                 if d["name"].startswith("span/")]
        if not spans:
            continue
        lines = [f"**{doc['name']}** (`{os.path.basename(path)}`, "
                 f"{doc.get('events', '?')} events, backend "
                 f"{doc.get('host', {}).get('backend', '?')})",
                 "",
                 "| phase | stage | calls | total | mean | min | max |",
                 "|---|---|---|---|---|---|---|"]
        for d in spans:
            stage = d["tags"].get("stage", "")
            mean = d["sum"] / max(d["n"], 1)
            lines.append(
                f"| {d['name'][len('span/'):]} | {stage} | {d['n']} "
                f"| {ms(d['sum'])}ms | {ms(mean)}ms "
                f"| {ms(d['min'])}ms | {ms(d['max'])}ms |")
        blocks.append("\n".join(lines))
    if not blocks:
        return "_metrics artifacts exist but carry no span distributions_"
    return "\n\n".join(blocks)


def streaming_table(path: str | None = None) -> str:
    """Headline table from BENCH_stream.json (repo root): virtual rounds/hour
    of the quorum-commit StreamEngine vs the synchronous deadline loop on the
    same faulted cells, plus the degradation-ladder rung histogram and the
    retry/merge ledger."""
    path = path or os.path.join(ROOT, "BENCH_stream.json")
    if not os.path.exists(path):
        return ("_no streaming artifact yet — run "
                "`PYTHONPATH=src python -m benchmarks.bench_stream`_")
    doc = json.load(open(path))
    lines = [f"`{os.path.basename(path)}` — quorum={doc['config']['stream']['quorum']}, "
             f"retry_budget={doc['config']['stream']['retry_budget']}, "
             f"{doc['config']['rounds']} rounds/cell, "
             f"deterministic replay: **{doc['deterministic']}**",
             "",
             "| scenario | faults | rph stream | rph sync | speedup | "
             "acc stream | acc sync | rungs 0/1/2/3 | retries | merged | "
             "dropped |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in doc["pairs"]:
        rungs = "/".join(str(x) for x in r["rungs"])
        lines.append(
            f"| {r['scenario']} | {r['faults']} "
            f"| {r['rounds_per_hour_stream']:.0f} "
            f"| {r['rounds_per_hour_sync']:.0f} "
            f"| {r['speedup']:.2f}x | {r['acc_stream']:.3f} "
            f"| {r['acc_sync']:.3f} | {rungs} | {r['retries']} "
            f"| {r['merged_inflight'] + r['gap_merged']} "
            f"| {r['stale_dropped']} |")
    return "\n".join(lines)


def generation_tables(path: str | None = None,
                      directory: str = SWEEP_ART) -> str:
    """AIGC dataplane tables from BENCH_gen.json (throughput grid +
    batched-vs-sequential serving of one K-vehicle round) and the
    `repro.exp` stepsweep artifact (accuracy vs sampler_steps under the
    measured-t0 planner coupling)."""
    path = path or os.path.join(ROOT, "BENCH_gen.json")
    if not os.path.exists(path):
        return ("_no generation artifact yet — run "
                "`PYTHONPATH=src python -m benchmarks.bench_gen`_")
    doc = json.load(open(path))
    res = doc["results"]
    m = doc["config"]["model"]
    b = res["batched_vs_sequential"]
    lines = [f"`{os.path.basename(path)}` — DDPM {m['timesteps']} steps x "
             f"width {m['base_width']}, {m['num_classes']} classes; one "
             f"K={b['k_vehicles']} round schedule (b*={b['b_star']}, "
             f"deployable stride {b['sampler_steps']}): fused dispatch "
             f"**{b['speedup']:.1f}x** over per-(vehicle,label) serving, "
             f"{b['speedup_vs_per_vehicle']:.1f}x over per-vehicle.",
             "",
             "| sampler_steps | fused | per-vehicle | per-(vehicle,label) "
             "| speedup (vs per-label / per-vehicle) |",
             "|---|---|---|---|---|"]
    for r in b.get("rows", [b]):
        lines.append(f"| {r['sampler_steps']} | {fmt(r['wall_s_batched'])} "
                     f"| {fmt(r['wall_s_per_vehicle'])} "
                     f"| {fmt(r['wall_s_per_label'])} "
                     f"| {r['speedup']:.2f}x / "
                     f"{r['speedup_vs_per_vehicle']:.2f}x |")
    lines += ["",
             "| bucket | sampler_steps | wall | samples/s | t0 (ms/img) |",
             "|---|---|---|---|---|"]
    for r in res["throughput"]:
        lines.append(f"| {r['bucket']} | {r['sampler_steps']} "
                     f"| {fmt(r['wall_s'])} | {r['samples_per_s']:.2f} "
                     f"| {r['t_per_image_s'] * 1e3:.1f} |")
    cx = res.get("crossover")
    if cx:
        lines += ["",
                  f"Compute/comm crossover (b={cx['b_schedule']} schedule "
                  f"vs t_bar={cx['t_bar_s']}s round window): generation "
                  f"stays within the comm-bound window up to "
                  f"**sampler_steps={cx['max_steps_within_window']}**.",
                  "",
                  "| sampler_steps | t0 (ms/img) | gen wall (b images) | "
                  "fits window |", "|---|---|---|---|"]
        for r in cx["points"]:
            lines.append(f"| {r['sampler_steps']} "
                         f"| {r['t_per_image_s'] * 1e3:.1f} "
                         f"| {fmt(r['gen_wall_s'])} "
                         f"| {'yes' if r['fits_round_window'] else 'no'} |")
    sw_path = os.path.join(directory, "bench_gen.stepsweep.json")
    acc = res.get("accuracy_vs_steps")
    if acc is None and os.path.exists(sw_path):
        acc = json.load(open(sw_path)).get("accuracy_vs_steps")
    if acc:
        lines += ["",
                  f"Accuracy vs sampler_steps (`generator=\"ddpm\"`, "
                  f"{acc['scenario']}, {acc['rounds']} rounds):",
                  "",
                  "| sampler_steps | final acc | b_gen total |",
                  "|---|---|---|"]
        for c in acc["cells"]:
            lines.append(f"| {c['sampler_steps']} "
                         f"| {c['final_accuracy']:.3f} "
                         f"| {c['b_gen_total']} |")
    return "\n".join(lines)


def theorem1_tables(directory: str = SWEEP_ART) -> str:
    """Per-scenario bound-tightness tables from *.theorem1.json, formatted
    by the same helper `Theorem1Report.to_markdown` uses."""
    from repro.exp import list_artifacts, load_artifact
    from repro.exp.analysis import per_scenario_markdown
    paths = list_artifacts("theorem1", directory)
    if not paths:
        return "_no theorem1 artifacts yet — run `benchmarks/theorem1.py`_"
    blocks = []
    for path in paths:
        doc = load_artifact(path, kind="theorem1")
        blocks.append(f"**{os.path.basename(path)}** "
                      f"(L* proxy {doc['loss_star']:.4f}, g_n={doc['g_n']})"
                      f"\n\n{per_scenario_markdown(doc['per_scenario'])}")
    return "\n\n".join(blocks)


def inject(md: str, marker: str, content: str) -> str:
    start = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- /AUTOGEN:{marker} -->"
    if start not in md:
        return md                               # marker absent: leave as-is
    pat = re.compile(re.escape(start) + ".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + content + "\n" + end, md)


def main():
    recs = load(ART)
    opt = load(ART_OPT)
    md = open(MD).read() if os.path.exists(MD) else SKELETON
    md = inject(md, "sweeps", sweep_tables())
    md = inject(md, "theorem1", theorem1_tables())
    md = inject(md, "obs-timings", obs_timing_tables())
    md = inject(md, "streaming", streaming_table())
    md = inject(md, "generation", generation_tables())
    md = inject(md, "roofline-sp", roofline_table(recs, "16x16", opt))
    md = inject(md, "roofline-mp", roofline_table(recs, "2x16x16"))
    md = inject(md, "dryrun", dryrun_summary(recs))
    n_ok = sum(1 for r in recs.values() if not r.get("skipped") and not r.get("error"))
    n_skip = sum(1 for r in recs.values() if r.get("skipped"))
    md = inject(md, "counts",
                f"**{len(recs)} combinations: {n_ok} compiled, {n_skip} "
                f"skipped per long-context policy, "
                f"{len(recs) - n_ok - n_skip} errors.**")
    open(MD, "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

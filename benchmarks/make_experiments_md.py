"""Regenerate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts. The narrative sections are maintained by hand; this
script rewrites only the blocks between the AUTOGEN markers.

  PYTHONPATH=src python benchmarks/make_experiments_md.py
"""
from __future__ import annotations

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "benchmarks", "artifacts")
ART_OPT = os.path.join(ROOT, "benchmarks", "artifacts_opt")
MD = os.path.join(ROOT, "EXPERIMENTS.md")


def load(d):
    out = {}
    for fn in sorted(glob.glob(os.path.join(d, "dryrun_*.json"))):
        r = json.load(open(fn))
        mesh = "2x16x16" if r.get("mesh", {}).get("pod") else "16x16"
        out[(r["arch"], r["shape"], mesh)] = r
    return out


def fmt(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def gib(b):
    return f"{b / 2**30:.1f}"


def roofline_table(recs, mesh="16x16", opt=None):
    lines = ["| arch | shape | compute | memory | collective | dominant | "
             "useful | HBM/dev (arg+temp) |" + (" opt: compute / temp / useful |" if opt else ""),
             "|---|---|---|---|---|---|---|---|" + ("---|" if opt else "")]
    for (arch, shape, m), r in sorted(recs.items()):
        if m != mesh:
            continue
        if r.get("skipped"):
            lines.append(f"| {arch} | {shape} | SKIP — {r['note'][:60]} |||||||"
                         + ("|" if opt else ""))
            continue
        mem = r["memory"]
        extra = ""
        if opt:
            o = opt.get((arch, shape, m))
            if o and not o.get("skipped"):
                extra = (f" {fmt(o['compute_term_s'])} / "
                         f"{gib(o['memory'].get('temp_size_in_bytes', 0))}GiB / "
                         f"{o['useful_flops_ratio'] and round(o['useful_flops_ratio'], 2)} |")
            else:
                extra = " — |"
        lines.append(
            f"| {arch} | {shape} | {fmt(r['compute_term_s'])} "
            f"| {fmt(r['memory_term_s'])} | {fmt(r['collective_term_s'])} "
            f"| {r['dominant']} "
            f"| {r['useful_flops_ratio'] and round(r['useful_flops_ratio'], 2)} "
            f"| {gib(mem.get('argument_size_in_bytes', 0))}+"
            f"{gib(mem.get('temp_size_in_bytes', 0))}GiB |" + extra)
    return "\n".join(lines)


def dryrun_summary(recs):
    lines = ["| arch | shape | mesh | compile | params | collective bytes "
             "(global) | by kind |", "|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(recs.items()):
        if r.get("skipped"):
            continue
        kinds = ", ".join(f"{k.split('-')[-1]}={v / 2**30:.0f}G"
                          for k, v in sorted(r["collective_by_kind"].items())
                          if v > 2**30)
        lines.append(f"| {arch} | {shape} | {m} | {r['compile_s']:.0f}s "
                     f"| {r['params'] / 1e9:.2f}B "
                     f"| {r['collective_bytes_global'] / 2**30:.0f} GiB "
                     f"| {kinds} |")
    return "\n".join(lines)


def inject(md: str, marker: str, content: str) -> str:
    start = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- /AUTOGEN:{marker} -->"
    pat = re.compile(re.escape(start) + ".*?" + re.escape(end), re.S)
    return pat.sub(start + "\n" + content + "\n" + end, md)


def main():
    recs = load(ART)
    opt = load(ART_OPT)
    md = open(MD).read()
    md = inject(md, "roofline-sp", roofline_table(recs, "16x16", opt))
    md = inject(md, "roofline-mp", roofline_table(recs, "2x16x16"))
    md = inject(md, "dryrun", dryrun_summary(recs))
    n_ok = sum(1 for r in recs.values() if not r.get("skipped") and not r.get("error"))
    n_skip = sum(1 for r in recs.values() if r.get("skipped"))
    md = inject(md, "counts",
                f"**{len(recs)} combinations: {n_ok} compiled, {n_skip} "
                f"skipped per long-context policy, "
                f"{len(recs) - n_ok - n_skip} errors.**")
    open(MD, "w").write(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()

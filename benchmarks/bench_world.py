"""repro.sim world benchmark: vectorized world-step throughput at 10k-100k
vehicles, plus a per-scenario GenFV accuracy sweep.

Throughput: a pure-traffic world (no data partitions) is stepped repeatedly;
each step is the full pipeline — eq.-24 road-load speed feedback, AR(1)
speed/shadowing innovations, position advance, departures, Poisson arrivals.
Reported as steps/sec and vehicle-steps/sec (population x step rate), the
number that has to hold up when the simulated cell scales far past the
paper's 40-vehicle operating point.

Scenario sweep: every registered scenario runs end-to-end through
`GenFVRunner.train()` at a reduced scale and reports final accuracy, mean
selected vehicles, and total mid-round dropouts — the knob-to-outcome table
the ROADMAP's scenario-diversity goal asks for.

  PYTHONPATH=src python -m benchmarks.bench_world [--quick] [--out PATH]

Writes BENCH_world.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to one population
size and a single 1-round scenario smoke (tier-1: tests/test_sim.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.base import GenFVConfig
from repro.core.mobility import coverage_half_length
from repro.sim import SCENARIOS, VehicularWorld, get_scenario

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_world.json")


def bench_throughput(n_vehicles: int, steps: int, dt: float = 3.0) -> Dict:
    """Step a pure-traffic world of ~n_vehicles and time the step loop."""
    scn = get_scenario("highway_free_flow")
    half_speed_ms = 90.0 / 3.6          # rough free-flow equilibrium speed
    cfg = dataclasses.replace(
        scn.apply(GenFVConfig()),
        m_max=4 * n_vehicles,           # keep eq. 24 out of the jam regime
        shadow_sigma_db=4.0,
    )
    chord = 2.0 * coverage_half_length(cfg)
    # arrivals balance departures so the population stays ~n_vehicles
    cfg = dataclasses.replace(cfg,
                              arrival_rate=n_vehicles * half_speed_ms / chord)
    scn = dataclasses.replace(scn, init_mean=float(n_vehicles))
    rng = np.random.default_rng(0)
    world = VehicularWorld(cfg, scn, n_partitions=0, rng=rng)

    for _ in range(2):                  # warmup (allocator, caches)
        world.step(rng, dt)
    pops = []
    with stopwatch() as sw:
        for _ in range(steps):
            world.step(rng, dt)
            pops.append(world.n)
    elapsed = sw.elapsed_s

    mean_pop = float(np.mean(pops))
    row = {
        "n_vehicles": n_vehicles,
        "mean_population": mean_pop,
        "steps": steps,
        "steps_per_sec": steps / elapsed,
        "vehicle_steps_per_sec": mean_pop * steps / elapsed,
        "arrivals": world.stats.arrivals,
        "departures": world.stats.departures,
    }
    emit(f"world/step_N{n_vehicles}", elapsed / steps * 1e6,
         f"veh_steps_per_sec={row['vehicle_steps_per_sec']:.3g}")
    return row


def bench_scenarios(scenarios: List[str], rounds: int, train_size: int,
                    width_mult: float, strategy: str = "genfv") -> List[Dict]:
    # imported lazily to keep the fl stack (CNN models, fleet engine, jit
    # caches) out of the throughput-only path; jax itself is already loaded
    # transitively via repro.core
    from repro.fl.rounds import GenFVRunner, RunConfig

    rows = []
    for name in scenarios:
        run = RunConfig(rounds=rounds, train_size=train_size, test_size=64,
                        width_mult=width_mult, strategy=strategy, seed=0,
                        scenario=name)
        fl_cfg = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=10)
        with stopwatch() as sw:
            res = GenFVRunner(run, fl_cfg=fl_cfg).train()
        elapsed = sw.elapsed_s
        row = {
            "scenario": name,
            "rounds": rounds,
            "final_accuracy": float(res.curve("accuracy")[-1]),
            "mean_selected": float(res.curve("selected").mean()),
            "total_dropped": int(res.curve("dropped").sum()),
            "mean_t_bar": float(res.curve("t_bar").mean()),
            "wall_s": elapsed,
        }
        rows.append(row)
        emit(f"world/scenario_{name}", elapsed / rounds * 1e6,
             f"acc={row['final_accuracy']:.3f} sel={row['mean_selected']:.1f} "
             f"drop={row['total_dropped']}")
    return rows


def run_bench(quick: bool = False) -> Dict:
    if quick:
        sizes, steps = (10_000,), 30
        sweep = dict(scenarios=["rush_hour"], rounds=1, train_size=256,
                     width_mult=0.0625)
    else:
        sizes, steps = (10_000, 30_000, 100_000), 100
        sweep = dict(scenarios=sorted(SCENARIOS), rounds=6, train_size=1200,
                     width_mult=0.125)
    throughput = [bench_throughput(n, steps) for n in sizes]
    scenarios = bench_scenarios(**sweep)
    return record("repro.sim world-step throughput + scenario sweep",
                  quick=quick, config={"sizes": list(sizes), "steps": steps,
                                       "sweep": sweep},
                  results={"throughput": throughput, "scenarios": scenarios},
                  throughput=throughput, sweep_config=sweep,
                  scenarios=scenarios)


def run(quick: bool = True) -> None:
    """benchmarks.run entry point: quick CSV-only sweep."""
    run_bench(quick=quick)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one population size, 1-round single-scenario smoke")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    with open(args.out, "a"):        # fail fast on an unwritable path
        pass                         # (append probe: keep prior results)
    print("name,us_per_call,derived")
    res = run_bench(quick=args.quick)
    write_json(res, args.out)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 8: objective value after solving each subproblem of the two-scale
algorithm (t_max = 3.0 s). Paper claim: the objective drops significantly
after each of SUBP1/2/3 and the BCD iteration converges."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.exp import save_artifact
from repro.core import bandwidth as bw
from repro.core import channel, gpu_model, mobility, power as pw
from repro.core.selection import select
from repro.core.two_scale import plan_round

MODEL_BITS = 11.2e6 * 32


def run() -> None:
    cfg = GenFVConfig(t_max=3.0)
    rng = np.random.default_rng(11)
    hists = rng.dirichlet(np.full(10, 0.5), size=40)
    sizes = rng.integers(500, 2000, size=40)
    fleet = mobility.sample_fleet(rng, cfg, hists, sizes)
    t0 = time.perf_counter()

    # stage 0: all in-range vehicles, equal share, min power
    n0 = channel.noise_watts(cfg)
    def stage_obj(sub, l, phi):
        d = np.array([mobility.rsu_distance(cfg, v.x) for v in sub])
        bp = cfg.unit_channel_gain * d ** (-cfg.path_loss_exp) / n0
        t_cp = np.array([gpu_model.train_time(v, 8) for v in sub])
        t_mu = pw.t_of_phi(MODEL_BITS, l * cfg.subcarrier_bw, bp, phi)
        return float(np.max(t_cp + t_mu))

    obj0 = stage_obj(fleet, bw.equal_share(len(fleet), cfg.num_subcarriers),
                     np.full(len(fleet), cfg.phi_min))

    sel = select(cfg, fleet, MODEL_BITS, 8)
    sub = [fleet[i] for i in np.flatnonzero(sel.alpha)]
    if not sub:
        emit("fig8_subproblems/none_selected", 0.0, "no feasible vehicles")
        return
    obj1 = stage_obj(sub, bw.equal_share(len(sub), cfg.num_subcarriers),
                     np.full(len(sub), cfg.phi_min))

    plan = plan_round(cfg, fleet, MODEL_BITS, batches=8)
    objs = [obj0, obj1] + plan.history
    dt = (time.perf_counter() - t0) * 1e6
    stages = ["init(all,equal,phimin)", "after_SUBP1"] + \
             [f"BCD_iter{i+1}" for i in range(len(plan.history))]
    for s, o in zip(stages, objs):
        emit(f"fig8_subproblems/{s}", dt, f"objective={o:.3f}s")
    emit("fig8_subproblems/summary", dt,
         f"monotone={all(a >= b - 1e-6 for a, b in zip(objs, objs[1:]))} "
         f"total_drop={objs[0] - objs[-1]:.3f}s")
    save_artifact("fig8_subproblems", "bcdtrace",
                  {"stages": stages, "objectives": objs,
                   "bcd_iters": plan.bcd_iters})


if __name__ == "__main__":
    run()

"""Fig. 5 + Table I: EMD value distribution vs Dirichlet alpha per dataset.

The (dataset x alpha) loop is the ordered `repro.exp.grid` cartesian
product, and the observed distributions land in one versioned artifact
(artifacts/fig5_emd.emdgrid.json) instead of ad-hoc prints only.

Validates the paper's claim that EMD decreases with alpha and that the
Table I thresholds sit inside the observed EMD ranges (so the constraint
eq. 29 actually separates vehicles)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.genfv_cifar import EMD_THRESHOLDS
from repro.core.emd import emd_many
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DATASET_CLASSES
from repro.exp import grid, save_artifact

ALPHAS = (0.1, 0.3, 0.5, 1.0)


def run() -> None:
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    rows = []
    prev_mean = {}
    labels_by_ds = {}
    for cell in grid(dataset=tuple(DATASET_CLASSES), alpha=ALPHAS):
        dataset, alpha = cell["dataset"], cell["alpha"]
        classes = DATASET_CLASSES[dataset]
        # one label draw per dataset (grid order is alpha-fastest, so the
        # rng consumption matches the seed benchmark's nested loops)
        if dataset not in labels_by_ds:
            labels_by_ds[dataset] = rng.integers(0, classes, size=20_000)
        labels = labels_by_ds[dataset]
        parts = dirichlet_partition(labels, 40, alpha, rng)
        hists = np.stack([np.bincount(labels[ix], minlength=classes)
                          / max(len(ix), 1) for ix in parts])
        emds = emd_many(hists)
        mean = float(emds.mean())
        thr = EMD_THRESHOLDS[dataset][alpha]
        # paper claim: heterogeneity falls as alpha rises
        ok_mono = dataset not in prev_mean or mean <= prev_mean[dataset] + 0.05
        # threshold must be discriminative (inside the support)
        ok_thr = emds.min() - 0.2 <= thr
        emit(f"fig5_emd/{dataset}/alpha{alpha}",
             (time.perf_counter() - t0) * 1e6,
             f"mean_emd={mean:.3f} thr={thr} mono={ok_mono} "
             f"thr_in_range={ok_thr}")
        prev_mean[dataset] = mean
        rows.append(dict(cell, mean_emd=mean, min_emd=float(emds.min()),
                         max_emd=float(emds.max()), threshold=thr,
                         monotone_ok=bool(ok_mono),
                         threshold_in_range=bool(ok_thr)))
    save_artifact("fig5_emd", "emdgrid", {"rows": rows})


if __name__ == "__main__":
    run()

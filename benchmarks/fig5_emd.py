"""Fig. 5 + Table I: EMD value distribution vs Dirichlet alpha per dataset.

Validates the paper's claim that EMD decreases with alpha and that the
Table I thresholds sit inside the observed EMD ranges (so the constraint
eq. 29 actually separates vehicles)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.genfv_cifar import EMD_THRESHOLDS
from repro.core.emd import emd_many
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DATASET_CLASSES


def run() -> None:
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for dataset, classes in DATASET_CLASSES.items():
        labels = rng.integers(0, classes, size=20_000)
        prev_mean = None
        for alpha in (0.1, 0.3, 0.5, 1.0):
            parts = dirichlet_partition(labels, 40, alpha, rng)
            hists = np.stack([np.bincount(labels[ix], minlength=classes)
                              / max(len(ix), 1) for ix in parts])
            emds = emd_many(hists)
            mean = float(emds.mean())
            thr = EMD_THRESHOLDS[dataset][alpha]
            # paper claim: heterogeneity falls as alpha rises
            ok_mono = prev_mean is None or mean <= prev_mean + 0.05
            # threshold must be discriminative (inside the support)
            ok_thr = emds.min() - 0.2 <= thr
            emit(f"fig5_emd/{dataset}/alpha{alpha}",
                 (time.perf_counter() - t0) * 1e6,
                 f"mean_emd={mean:.3f} thr={thr} mono={ok_mono} "
                 f"thr_in_range={ok_thr}")
            prev_mean = mean


if __name__ == "__main__":
    run()

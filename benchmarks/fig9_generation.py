"""Fig. 9: cumulative number of generated images per label across rounds for
the three datasets. Paper claims: per-round totals are similar under the
same wireless conditions; more classes => fewer images per label; growth
slows as the augmented-model training time rises (eq. 48 feedback)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.generation import DiffusionService, label_schedule
from repro.core.two_scale import plan_round
from repro.data.synthetic import DATASET_CLASSES

MODEL_BITS = 11.2e6 * 32
ROUNDS = 12


def run() -> None:
    cfg = GenFVConfig()
    svc = DiffusionService(steps=cfg.diffusion_steps)
    for dataset, classes in DATASET_CLASSES.items():
        rng = np.random.default_rng(5)
        cum = np.zeros(classes, np.int64)
        b_prev = 0
        increments = []
        t0 = time.perf_counter()
        for t in range(ROUNDS):
            hists = rng.dirichlet(np.full(classes, 0.5), size=30)
            sizes = rng.integers(500, 2000, size=30)
            fleet = mobility.sample_fleet(rng, cfg, hists, sizes)
            plan = plan_round(cfg, fleet, MODEL_BITS, batches=8,
                              b_prev=b_prev, svc=svc)
            b_prev = plan.b_gen
            cum += label_schedule(plan.b_gen, classes)
            increments.append(plan.b_gen)
        dt = (time.perf_counter() - t0) * 1e6 / ROUNDS
        slowing = (np.mean(increments[-4:]) <= np.mean(increments[:4]) + 1)
        emit(f"fig9_generation/{dataset}", dt,
             f"total={int(cum.sum())} per_label_mean={cum.mean():.1f} "
             f"per_label_max={int(cum.max())} growth_slows={slowing}")


if __name__ == "__main__":
    run()

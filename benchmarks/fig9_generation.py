"""Fig. 9: cumulative number of generated images per label across rounds for
the three datasets. Paper claims: per-round totals are similar under the
same wireless conditions; more classes => fewer images per label; growth
slows as the augmented-model training time rises (eq. 48 feedback).

The per-dataset round loops are planned in ONE `plan_rounds_batched`
dispatch per round across the three datasets (they share GenFVConfig and
model_bits; only b_prev and the fleet draw differ), and the cumulative
schedules land in a versioned artifact."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.generation import DiffusionService, label_schedule
from repro.core.two_scale import plan_rounds_batched
from repro.data.synthetic import DATASET_CLASSES
from repro.exp import save_artifact

MODEL_BITS = 11.2e6 * 32
ROUNDS = 12


def run() -> None:
    cfg = GenFVConfig()
    svc = DiffusionService(steps=cfg.diffusion_steps)
    datasets = list(DATASET_CLASSES)
    rngs = {d: np.random.default_rng(5) for d in datasets}
    cum = {d: np.zeros(DATASET_CLASSES[d], np.int64) for d in datasets}
    b_prev = {d: 0 for d in datasets}
    increments = {d: [] for d in datasets}

    t0 = time.perf_counter()
    for t in range(ROUNDS):
        fleets = []
        for d in datasets:
            classes = DATASET_CLASSES[d]
            rng = rngs[d]
            hists = rng.dirichlet(np.full(classes, 0.5), size=30)
            sizes = rng.integers(500, 2000, size=30)
            fleets.append(mobility.sample_fleet(rng, cfg, hists, sizes))
        plans = plan_rounds_batched(cfg, fleets, MODEL_BITS, batches=8,
                                    b_prevs=[b_prev[d] for d in datasets],
                                    svc=svc)
        for d, plan in zip(datasets, plans):
            b_prev[d] = plan.b_gen
            cum[d] += label_schedule(plan.b_gen, DATASET_CLASSES[d])
            increments[d].append(plan.b_gen)
    dt = (time.perf_counter() - t0) * 1e6 / (ROUNDS * len(datasets))

    rows = []
    for d in datasets:
        inc = increments[d]
        slowing = (np.mean(inc[-4:]) <= np.mean(inc[:4]) + 1)
        emit(f"fig9_generation/{d}", dt,
             f"total={int(cum[d].sum())} per_label_mean={cum[d].mean():.1f} "
             f"per_label_max={int(cum[d].max())} growth_slows={slowing}")
        rows.append({"dataset": d, "increments": inc,
                     "cumulative_per_label": cum[d],
                     "growth_slows": bool(slowing)})
    save_artifact("fig9_generation", "genschedule", {"rows": rows,
                                                     "rounds": ROUNDS})


if __name__ == "__main__":
    run()

"""Streaming-round benchmark: sustained rounds/hour under churn.

For each (scenario, fault schedule) pair the same faulted cell runs twice —
once through the synchronous `GenFVRunner.train()` loop (every round waits
out its deadline) and once through the event-driven `StreamEngine` (rounds
commit at quorum arrival, failed uploads retry with backoff, late updates
merge on arrival). Both clocks are VIRTUAL: the sync baseline's round time
is the realized `t_round` (deadline-clipped), the stream's is the engine's
explicitly-advanced clock, so the headline ``rounds_per_hour`` ratio is a
property of the protocol, not the host. A second stream run replays the
same (seed, schedule) and must reproduce the commit sequence bitwise — that
feeds the ``deterministic`` flag. Headline pairs are the churn stressors:
`platoon` + platoon_mass_dropout and `rush_hour` + rush_hour_deep_fade.

  PYTHONPATH=src python -m benchmarks.bench_stream [--quick] [--out PATH]

Writes BENCH_stream.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to the two headline
pairs at 3 rounds on a tiny train set (tier-1: tests/test_stream.py).
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.base import GenFVConfig, StreamConfig
from repro.fl.rounds import GenFVRunner, RunConfig
from repro.fl.stream import StreamEngine

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_stream.json")

HEADLINE = [("platoon", "platoon_mass_dropout"),
            ("rush_hour", "rush_hour_deep_fade")]
EXTRA = [("highway_free_flow", "compute_stragglers"),
         ("highway_free_flow", "poison_minority"),
         ("urban_stop_go", "mixed_stress")]

#: streaming policy under test — quorum commit + cadence + bounded retries
STREAM = dict(quorum=0.6, cadence_s=0.1, deadline_slack=0.25, retry_budget=2)


def make_runs(quick: bool):
    sizes = (dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
             if quick else
             dict(rounds=8, train_size=600, test_size=64, width_mult=0.0625))
    pairs = HEADLINE if quick else HEADLINE + EXTRA
    return sizes, pairs


def fl_cfg(quick: bool) -> GenFVConfig:
    return GenFVConfig(batch_size=8, local_steps=2,
                       num_vehicles=6 if quick else 10)


def _stream_run(run: RunConfig, cfg: GenFVConfig):
    runner = GenFVRunner(run, fl_cfg=cfg)
    eng = StreamEngine(runner, StreamConfig(**STREAM))
    res = eng.run()
    return runner, eng, res


def _sync_virtual_s(res, cfg: GenFVConfig) -> float:
    """Virtual seconds the synchronous loop spends: realized round time for
    planned rounds, a full deadline for empty ones (the RSU still waits)."""
    t_bar = res.curve("t_bar")
    t_round = res.curve("t_round")
    return float(np.where(t_bar > 0, t_round, cfg.t_max).sum())


def run(quick: bool = True, out: str | None = None) -> dict:
    sizes, pairs = make_runs(quick)
    cfg = fl_cfg(quick)

    rows = []
    deterministic = True
    sw = stopwatch()
    for scenario, fault in pairs:
        frun = RunConfig(strategy="genfv", scenario=scenario, seed=0,
                         faults=fault, **sizes)
        sync_res = GenFVRunner(frun, fl_cfg=cfg).train()
        _, eng, stream_res = _stream_run(frun, cfg)
        _, eng2, stream_res2 = _stream_run(frun, cfg)
        same = (eng.slogs == eng2.slogs
                and stream_res.logs == stream_res2.logs)
        deterministic &= same

        sync_s = _sync_virtual_s(sync_res, cfg)
        stream_s = float(eng.now)
        rungs = [sum(1 for s in eng.slogs if s.rung == r) for r in range(4)]
        row = {
            "scenario": scenario,
            "faults": fault,
            "rounds": len(eng.slogs),
            "virtual_s_sync": sync_s,
            "virtual_s_stream": stream_s,
            "rounds_per_hour_sync": 3600.0 * len(sync_res.logs) / sync_s,
            "rounds_per_hour_stream": 3600.0 * len(eng.slogs) / stream_s,
            "speedup": sync_s / stream_s,
            "acc_sync": float(sync_res.curve("accuracy")[-1]),
            "acc_stream": float(stream_res.curve("accuracy")[-1]),
            "rungs": rungs,
            "retries": int(sum(s.retries for s in eng.slogs)),
            "exhausted": int(sum(s.exhausted for s in eng.slogs)),
            "merged_inflight": int(sum(s.merged_inflight
                                       for s in eng.slogs)),
            "gap_merged": int(sum(s.gap_merged for s in eng.slogs)),
            "stale_dropped": int(sum(s.stale_dropped for s in eng.slogs)),
            "still_inflight": len(eng.inflight),
            "deterministic": same,
            "accuracy_curve_stream": stream_res.curve("accuracy").tolist(),
        }
        rows.append(row)
        emit(f"stream/{scenario}+{fault}",
             sw.elapsed_s * 1e6 / max(len(rows), 1),
             f"rph_stream={row['rounds_per_hour_stream']:.1f} "
             f"rph_sync={row['rounds_per_hour_sync']:.1f} "
             f"x{row['speedup']:.2f} acc={row['acc_stream']:.3f} "
             f"rungs={rungs} retry={row['retries']} "
             f"merged={row['merged_inflight'] + row['gap_merged']} "
             f"det={same}")

    doc = record("async streaming RSU rounds (fl/stream.py quorum commit)",
                 quick=quick,
                 config={"rounds": sizes["rounds"], "stream": dict(STREAM)},
                 results=rows, rounds=sizes["rounds"], pairs=rows,
                 deterministic=deterministic, wall_s=sw.elapsed_s)
    write_json(doc, out or DEFAULT_OUT, indent=1)
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    doc = run(quick=args.quick, out=args.out)
    return 0 if doc["deterministic"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Fig. 6: FL training loss / testing accuracy under the five vehicle
selection strategies (GenFV proposed, FedAvg, No-EMD, MADCA-FL, OCEAN-a).

Paper claims validated: (1) every scheme converges; (2) feature-aware
schemes beat random FedAvg; (3) the proposed EMD+mobility selection is the
best of the five. Reduced scale (CPU): width-mult 0.125 CNN, procedural
CIFAR10-like data — orderings, not absolute accuracies (DESIGN.md §2)."""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import ART, emit, ensure_art
from repro.configs.base import GenFVConfig
from repro.fl.rounds import GenFVRunner, RunConfig

ROUNDS = 24
STRATS = ("genfv", "fedavg", "no_emd", "madca", "ocean")


def run(rounds: int = ROUNDS) -> None:
    ensure_art()
    out = {}
    # full ResNet-18 upload cost over the simulated channel even though the
    # trained CNN is width-reduced for CPU (model_bits below)
    fl_cfg = GenFVConfig(batch_size=32, local_steps=8, num_vehicles=12)
    for strat in STRATS:
        t0 = time.perf_counter()
        r = GenFVRunner(RunConfig(dataset="cifar10", alpha=0.3, rounds=rounds,
                                  strategy=strat, train_size=2000,
                                  test_size=192, width_mult=0.125, seed=5,
                                  model_bits=11.2e6 * 32),
                        fl_cfg=fl_cfg)
        res = r.train()
        acc = res.curve("accuracy")
        loss = res.curve("loss")
        out[strat] = {"accuracy": acc.tolist(), "loss": loss.tolist()}
        emit(f"fig6_selection/{strat}",
             (time.perf_counter() - t0) * 1e6 / rounds,
             f"final_acc={acc[-1]:.3f} mean_last3={acc[-3:].mean():.3f} "
             f"loss_drop={loss[0] - loss[-1]:.3f}")
    with open(f"{ART}/fig6_selection.json", "w") as f:
        json.dump(out, f, indent=1)
    best = max(out, key=lambda s: np.mean(out[s]["accuracy"][-3:]))
    # honest note: at this reduced scale (20-ish rounds, width-0.125 CNN,
    # procedural data) the selection schemes mostly separate on *stability*
    # rather than final accuracy; the paper's full ordering needs its scale.
    emit("fig6_selection/summary", 0.0,
         f"best_at_this_scale={best} (paper, at full scale: genfv)")


if __name__ == "__main__":
    run()

"""Fig. 6: FL training loss / testing accuracy under the five vehicle
selection strategies (GenFV proposed, FedAvg, No-EMD, MADCA-FL, OCEAN-a).

One `repro.exp` sweep over the strategy axis: the five cells share one
dataset build and FleetEngine, and their per-round SUBP2-4 plans go
through a single batched `plan_rounds_batched` dispatch (all five
strategies share the GenFVConfig/model_bits planning group).

Paper claims validated: (1) every scheme converges; (2) feature-aware
schemes beat random FedAvg; (3) the proposed EMD+mobility selection is the
best of the five. Reduced scale (CPU): width-mult 0.125 CNN, procedural
CIFAR10-like data — orderings, not absolute accuracies (DESIGN.md §2)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl.rounds import RunConfig

ROUNDS = 24
STRATS = ("genfv", "fedavg", "no_emd", "madca", "ocean")


def run(rounds: int = ROUNDS) -> None:
    spec = ExperimentSpec(
        name="fig6_selection",
        strategies=STRATS,
        alphas=(0.3,),
        # full ResNet-18 upload cost over the simulated channel even though
        # the trained CNN is width-reduced for CPU (model_bits below)
        base=RunConfig(dataset="cifar10", rounds=rounds, train_size=2000,
                       test_size=192, width_mult=0.125, seed=5,
                       model_bits=11.2e6 * 32),
    )
    fl_cfg = GenFVConfig(batch_size=32, local_steps=8, num_vehicles=12)
    t0 = time.perf_counter()
    result = Sweep(spec, fl_cfg=fl_cfg).run()
    dt = (time.perf_counter() - t0) * 1e6 / (rounds * spec.n_cells)
    result.save()

    finals = {}
    for strat in STRATS:
        acc = result.curve("accuracy", strategy=strat)
        loss = result.curve("loss", strategy=strat)
        finals[strat] = float(np.mean(acc[-3:]))
        emit(f"fig6_selection/{strat}", dt,
             f"final_acc={acc[-1]:.3f} mean_last3={acc[-3:].mean():.3f} "
             f"loss_drop={loss[0] - loss[-1]:.3f}")
    best = max(finals, key=finals.get)
    # honest note: at this reduced scale (20-ish rounds, width-0.125 CNN,
    # procedural data) the selection schemes mostly separate on *stability*
    # rather than final accuracy; the paper's full ordering needs its scale.
    emit("fig6_selection/summary", 0.0,
         f"best_at_this_scale={best} (paper, at full scale: genfv) "
         f"batched_dispatches={result.meta['planner_dispatches']}")


if __name__ == "__main__":
    run()

"""Roofline reader: aggregates the dry-run artifacts into the §Roofline table
(compute/memory/collective terms, dominant bottleneck, MODEL_FLOPS ratio).
Run after `python -m repro.launch.dryrun --all --both-meshes`."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ART, emit

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load() -> list[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(ART, "dryrun_*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def run() -> None:
    recs = load()
    if not recs:
        emit("roofline/missing", 0.0,
             "no dry-run artifacts; run repro.launch.dryrun --all first")
        return
    n_ok = n_skip = n_err = 0
    for r in recs:
        mesh = "2x16x16" if r.get("mesh", {}).get("pod") else "16x16"
        name = f"roofline/{r['arch']}/{r['shape']}/{mesh}"
        if r.get("error"):
            n_err += 1
            emit(name, 0.0, f"ERROR {r['error'][:80]}")
            continue
        if r.get("skipped"):
            n_skip += 1
            emit(name, 0.0, f"SKIP {r.get('note', '')[:80]}")
            continue
        n_ok += 1
        ratio = r.get("useful_flops_ratio")
        emit(name, r.get("compile_s", 0.0) * 1e6,
             f"compute={fmt_s(r['compute_term_s'])} "
             f"mem={fmt_s(r['memory_term_s'])} "
             f"coll={fmt_s(r['collective_term_s'])} "
             f"dom={r['dominant']} "
             f"useful={ratio and round(ratio, 3)} "
             f"hbm/dev={r['memory'].get('argument_size_in_bytes', 0) / 2**30:.2f}"
             f"+{r['memory'].get('temp_size_in_bytes', 0) / 2**30:.2f}GiB")
    emit("roofline/summary", 0.0,
         f"ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    run()

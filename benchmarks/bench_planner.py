"""Two-scale planner benchmark: jitted single-plan latency and vmapped
multi-fleet throughput vs the numpy reference solver.

Single-plan: one `plan_round` on an N=64 fleet, jax kernel vs numpy BCD —
the jitted path must be no slower (acceptance bar) since the FL runner
calls it every round.

Batched: F independent fleets planned in ONE `plan_rounds_batched`
dispatch vs F sequential numpy `plan_round` calls — the multi-seed /
multi-strategy sweep shape (benchmarks/fig6-8, examples/scenario_sweep).
Acceptance bar: >=5x at F>=8, N=64 on CPU.

  PYTHONPATH=src python -m benchmarks.bench_planner [--quick] [--out PATH]

Writes BENCH_planner.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to F=4 fleets and
3 timing reps (tier-1 smoke: tests/test_planner.py).
"""
from __future__ import annotations

import argparse
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.two_scale import plan_round, plan_rounds_batched

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_planner.json")
MODEL_BITS = 11.2e6 * 32
N_VEHICLES = 64
BATCHES = 8


def _fleet(seed: int, cfg: GenFVConfig):
    rng = np.random.default_rng(seed)
    hists = rng.dirichlet(np.full(10, 0.5), size=N_VEHICLES)
    sizes = rng.integers(500, 2000, size=N_VEHICLES)
    return mobility.sample_fleet(rng, cfg, hists, sizes)


def _median_ms(fn, reps: int) -> float:
    ts = []
    for _ in range(reps):
        with stopwatch() as sw:
            fn()
        ts.append(sw.elapsed_s)
    return float(np.median(ts)) * 1e3


def bench_single(cfg: GenFVConfig, reps: int) -> Dict:
    fleet = _fleet(0, cfg)
    k = len(plan_round(cfg, fleet, MODEL_BITS, BATCHES,
                       planner="numpy").selected)
    plan_round(cfg, fleet, MODEL_BITS, BATCHES, planner="jax")  # compile
    jax_ms = _median_ms(
        lambda: plan_round(cfg, fleet, MODEL_BITS, BATCHES, planner="jax"),
        reps)
    numpy_ms = _median_ms(
        lambda: plan_round(cfg, fleet, MODEL_BITS, BATCHES, planner="numpy"),
        reps)
    row = {"n_vehicles": N_VEHICLES, "selected": k, "reps": reps,
           "numpy_ms": numpy_ms, "jax_ms": jax_ms,
           "speedup": numpy_ms / jax_ms}
    emit("planner/single_plan", jax_ms * 1e3,
         f"numpy_ms={numpy_ms:.3f} jax_ms={jax_ms:.3f} "
         f"speedup={row['speedup']:.2f} K={k}")
    return row


def bench_batched(cfg: GenFVConfig, n_fleets: int, reps: int) -> Dict:
    fleets = [_fleet(100 + s, cfg) for s in range(n_fleets)]
    warm = plan_rounds_batched(cfg, fleets, MODEL_BITS, BATCHES)  # compile
    ks = [len(p.selected) for p in warm]
    jax_ms = _median_ms(
        lambda: plan_rounds_batched(cfg, fleets, MODEL_BITS, BATCHES), reps)
    numpy_ms = _median_ms(
        lambda: [plan_round(cfg, f, MODEL_BITS, BATCHES, planner="numpy")
                 for f in fleets], reps)
    row = {"n_fleets": n_fleets, "n_vehicles": N_VEHICLES, "reps": reps,
           "selected_per_fleet": ks,
           "numpy_ms": numpy_ms, "jax_ms": jax_ms,
           "numpy_plans_per_sec": n_fleets / (numpy_ms / 1e3),
           "jax_plans_per_sec": n_fleets / (jax_ms / 1e3),
           "speedup": numpy_ms / jax_ms}
    emit(f"planner/batched_F{n_fleets}", jax_ms * 1e3 / n_fleets,
         f"plans_per_sec={row['jax_plans_per_sec']:.0f} "
         f"speedup={row['speedup']:.2f}x")
    return row


def run_bench(quick: bool = False) -> Dict:
    cfg = GenFVConfig(num_vehicles=N_VEHICLES)
    if quick:
        reps, fleet_counts = 3, (4,)
    else:
        reps, fleet_counts = 15, (8, 16, 32)
    single = bench_single(cfg, reps)
    batched = [bench_batched(cfg, f, reps) for f in fleet_counts]
    return record("two-scale planner: jitted single-plan + vmapped batched",
                  quick=quick,
                  config={"n_vehicles": N_VEHICLES,
                          "model_bits": MODEL_BITS, "batches": BATCHES},
                  results={"single": single, "batched": batched},
                  single=single, batched=batched)


def run(quick: bool = True) -> None:
    """benchmarks.run entry point: quick CSV-only sweep."""
    run_bench(quick=quick)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small fleet count, few reps (tier-1 smoke)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help=f"output JSON path (default {DEFAULT_OUT})")
    args = ap.parse_args(argv)

    with open(args.out, "a"):        # fail fast on an unwritable path
        pass                         # (append probe: keep prior results)
    print("name,us_per_call,derived")
    res = run_bench(quick=args.quick)
    write_json(res, args.out)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

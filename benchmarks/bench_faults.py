"""Fault-tolerance benchmark: accuracy/delay degradation under the seeded
fault schedules of fl/faults.py.

For each (scenario, fault schedule) pair the same cell runs fault-free and
faulted (twice — the second faulted run checks the round-keyed injection is
deterministic), reporting the accuracy degradation, the realized-delay
inflation (mean t_round / mean t_bar) and the fault ledger totals
(dropped/late/rejected/stale_merged). Headline pairs stress the two recovery
paths: `platoon` + platoon_mass_dropout (a convoy exits together, SUBP1's
admitted set collapses mid-round) and `rush_hour` + rush_hour_deep_fade
(uploads suddenly cost 20 dB more at the planned (l, phi), the
deadline/staleness machinery carries the round).

  PYTHONPATH=src python -m benchmarks.bench_faults [--quick] [--out PATH]

Writes BENCH_faults.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to the two headline
pairs at 3 rounds on a tiny train set (tier-1: tests/test_faults.py).
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.base import GenFVConfig
from repro.fl.rounds import GenFVRunner, RunConfig

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_faults.json")

HEADLINE = [("platoon", "platoon_mass_dropout"),
            ("rush_hour", "rush_hour_deep_fade")]
EXTRA = [("highway_free_flow", "compute_stragglers"),
         ("highway_free_flow", "poison_minority"),
         ("urban_stop_go", "mixed_stress")]

#: curves that must replay identically between two fresh faulted runners
DET_KEYS = ("selected", "dropped", "late", "rejected", "stale_merged",
            "t_round", "loss", "accuracy")


def make_runs(quick: bool):
    sizes = (dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
             if quick else
             dict(rounds=8, train_size=600, test_size=64, width_mult=0.0625))
    pairs = HEADLINE if quick else HEADLINE + EXTRA
    return sizes, pairs


def fl_cfg(quick: bool) -> GenFVConfig:
    return GenFVConfig(batch_size=8, local_steps=2,
                       num_vehicles=6 if quick else 10)


def run(quick: bool = True, out: str | None = None) -> dict:
    sizes, pairs = make_runs(quick)
    cfg = fl_cfg(quick)

    rows = []
    deterministic = True
    sw = stopwatch()
    for scenario, fault in pairs:
        base_run = RunConfig(strategy="genfv", scenario=scenario, seed=0,
                             **sizes)
        fault_run = RunConfig(strategy="genfv", scenario=scenario, seed=0,
                              faults=fault, **sizes)
        base = GenFVRunner(base_run, fl_cfg=cfg).train()
        faulted = GenFVRunner(fault_run, fl_cfg=cfg).train()
        replay = GenFVRunner(fault_run, fl_cfg=cfg).train()
        same = all(np.array_equal(faulted.curve(k), replay.curve(k))
                   for k in DET_KEYS)
        deterministic &= same

        t_bar = faulted.curve("t_bar")
        t_round = faulted.curve("t_round")
        realized = t_bar > 0                # rounds that actually planned
        inflation = (float(t_round[realized].mean() / t_bar[realized].mean())
                     if realized.any() else 1.0)
        row = {
            "scenario": scenario,
            "faults": fault,
            "acc_baseline": float(base.curve("accuracy")[-1]),
            "acc_faulted": float(faulted.curve("accuracy")[-1]),
            "acc_degradation": float(base.curve("accuracy")[-1]
                                     - faulted.curve("accuracy")[-1]),
            "delay_inflation": inflation,
            "dropped": int(faulted.curve("dropped").sum()),
            "late": int(faulted.curve("late").sum()),
            "rejected": int(faulted.curve("rejected").sum()),
            "stale_merged": int(faulted.curve("stale_merged").sum()),
            "deterministic": same,
            "accuracy_curve_baseline": base.curve("accuracy").tolist(),
            "accuracy_curve_faulted": faulted.curve("accuracy").tolist(),
        }
        rows.append(row)
        emit(f"faults/{scenario}+{fault}",
             sw.elapsed_s * 1e6 / max(len(rows), 1),
             f"acc={row['acc_faulted']:.3f} "
             f"degr={row['acc_degradation']:+.3f} "
             f"delay_x={row['delay_inflation']:.2f} "
             f"drop={row['dropped']} late={row['late']} "
             f"rej={row['rejected']} merged={row['stale_merged']} "
             f"det={same}")

    doc = record("fault-tolerant GenFV rounds (fl/faults.py schedules)",
                 quick=quick, config={"rounds": sizes["rounds"]},
                 results=rows, rounds=sizes["rounds"], pairs=rows,
                 deterministic=deterministic, wall_s=sw.elapsed_s)
    write_json(doc, out or DEFAULT_OUT, indent=1)
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    doc = run(quick=args.quick, out=args.out)
    return 0 if doc["deterministic"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

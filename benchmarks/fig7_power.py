"""Fig. 7: objective value (min-max delay) vs maximum uplink power under
different t_max constraints. Paper claims: delay falls as phi_max rises;
smaller t_max keeps the feasible objective lower.

The four phi_max variants of each t_max share one cohort (alpha fixed, the
paper's claim is about the optimizer given a cohort) and identical channel
constants, so they are planned in ONE vmapped dispatch via
`plan_rounds_batched` — the sweep is 4 fleets x 1 dispatch instead of 4
sequential BCD runs.

Note: the cohort data is now drawn ONCE per t_max (the pre-batching code
redrew hists/sizes for every phi value, advancing the outer rng), so the
emitted objective values differ from figures generated before PR 3 — the
paper claims evaluated here are unchanged.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.selection import select
from repro.core.two_scale import plan_rounds_batched
from repro.exp import save_artifact

MODEL_BITS = 11.2e6 * 32
PHI_SWEEP = (0.3, 0.5, 0.7, 1.0)


def run() -> None:
    rng = np.random.default_rng(3)
    rows = []
    for t_max in (2.5, 3.0, 4.0):
        cfg = GenFVConfig(t_max=t_max)
        hists = rng.dirichlet(np.full(10, 0.5), size=40)
        sizes = rng.integers(500, 2000, size=40)
        base = mobility.sample_fleet(np.random.default_rng(7), cfg,
                                     hists, sizes)
        # one fleet copy per phi_max cap; channel/GPU draws shared
        fleets = [[dataclasses.replace(v, phi_max=p) for v in base]
                  for p in PHI_SWEEP]
        # fix the participant set across the phi sweep at the lowest cap
        alpha0 = select(cfg, fleets[0], MODEL_BITS, batches=8).alpha
        overrides = [alpha0] * len(fleets)
        # warmup: keep one-time jit compilation out of the timed dispatch
        plan_rounds_batched(cfg, fleets, MODEL_BITS, batches=8,
                            alpha_overrides=overrides)
        t0 = time.perf_counter()
        plans = plan_rounds_batched(cfg, fleets, MODEL_BITS, batches=8,
                                    alpha_overrides=overrides)
        dt = (time.perf_counter() - t0) * 1e6 / len(fleets)
        prev = None
        for phi_max, plan in zip(PHI_SWEEP, plans):
            obj = plan.t_bar if plan.selected else float("nan")
            mono = prev is None or not np.isfinite(obj) or obj <= prev + 0.05
            emit(f"fig7_power/tmax{t_max}/phi{phi_max}", dt,
                 f"objective={obj:.3f}s selected={len(plan.selected)} "
                 f"monotone_ok={mono}")
            rows.append({"t_max": t_max, "phi_max": phi_max,
                         "objective_s": obj,
                         "selected": len(plan.selected),
                         "monotone_ok": bool(mono),
                         "us_per_fleet": dt})
            if np.isfinite(obj):
                prev = obj
    save_artifact("fig7_power", "powergrid", {"rows": rows})


if __name__ == "__main__":
    run()

"""Fig. 7: objective value (min-max delay) vs maximum uplink power under
different t_max constraints. Paper claims: delay falls as phi_max rises;
smaller t_max keeps the feasible objective lower."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.two_scale import plan_round

MODEL_BITS = 11.2e6 * 32


def run() -> None:
    rng = np.random.default_rng(3)
    for t_max in (2.5, 3.0, 4.0):
        prev = None
        alpha0 = None
        for phi_max in (0.3, 0.5, 0.7, 1.0):
            cfg = GenFVConfig(t_max=t_max, phi_max=phi_max)
            hists = rng.dirichlet(np.full(10, 0.5), size=40)
            sizes = rng.integers(500, 2000, size=40)
            fleet = mobility.sample_fleet(np.random.default_rng(7), cfg,
                                          hists, sizes)
            for v in fleet:                     # sweep the fleet's power cap
                v.phi_max = phi_max
            t0 = time.perf_counter()
            # fix the participant set across the phi sweep (the paper's
            # claim is about the optimizer given a cohort, not selection)
            plan = plan_round(cfg, fleet, MODEL_BITS, batches=8,
                              alpha_override=alpha0)
            if alpha0 is None:
                alpha0 = plan.alpha
            dt = (time.perf_counter() - t0) * 1e6
            obj = plan.t_bar if plan.selected else float("nan")
            mono = prev is None or not np.isfinite(obj) or obj <= prev + 0.05
            emit(f"fig7_power/tmax{t_max}/phi{phi_max}", dt,
                 f"objective={obj:.3f}s selected={len(plan.selected)} "
                 f"monotone_ok={mono}")
            if np.isfinite(obj):
                prev = obj


if __name__ == "__main__":
    run()

"""Theorem 1: convergence-bound curves and the EMD-weighting rationale.

Shows the bound (i) contracts geometrically in hT, (ii) worsens with the
gradient-divergence bounds lambda_n = EMD_n * g_n, and (iii) is minimized
at an interior kappa2 when the AIGC divergence lambda_a is below the fleet
average — the analytical justification for eq. (4)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import convergence
from repro.core.emd import kappas


def run() -> None:
    p = convergence.ConvergenceParams(eta=0.01, varrho=10.0, mu=0.5, h=4,
                                      lambda_a=0.08)
    rhos = np.full(8, 1 / 8)
    t0 = time.perf_counter()
    for emd_bar in (0.4, 0.8, 1.2, 1.6):
        lams = np.full(8, emd_bar * 0.25)        # lambda_n = EMD_n * g_n
        k1, k2 = kappas(emd_bar)
        b_paper = convergence.bound(p, 200, rhos, lams, k1, k2)
        b_noaug = convergence.bound(p, 200, rhos, lams, 1.0, 0.0)
        # best kappa2 on a grid
        grid = [(kk2, convergence.bound(p, 200, rhos, lams, 1 - kk2, kk2))
                for kk2 in np.linspace(0, 1, 21)]
        k2_star, b_star = min(grid, key=lambda g: g[1])
        emit(f"theorem1/emd{emd_bar}", (time.perf_counter() - t0) * 1e6,
             f"bound_paper_k2={b_paper:.4f} bound_no_aug={b_noaug:.4f} "
             f"paper_beats_noaug={b_paper <= b_noaug + 1e-9} "
             f"k2_paper={k2:.3f} k2_grid_opt={k2_star:.2f}")


if __name__ == "__main__":
    run()

"""Theorem 1: convergence bound vs realized training, per scenario.

Runs entirely through `repro.exp`: one `ExperimentSpec` grid (strategy x
scenario), one `Sweep` whose SUBP2-4 planning goes through the batched
`plan_rounds_batched` dispatch, then `theorem1_comparison` evaluates the
bound (core/convergence.py) against every cell's realized loss curve and
aggregates bound tightness per scenario — the ROADMAP's
scenario-conditioned comparison.

Also keeps the analytic eq.-4 rationale the seed benchmark validated: the
bound (i) worsens with the divergence bounds lambda_n = EMD_n * g_n and
(ii) is minimized at an interior kappa2 when lambda_a is below the fleet
average.

Artifacts (committed): artifacts/theorem1.sweep.json +
artifacts/theorem1.theorem1.json + artifacts/theorem1.metrics.json (the
obs tracer's per-phase timings and planner/fault counters for the same
8-cell sweep; EXPERIMENTS.md renders its span table).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs.base import GenFVConfig
from repro.core import convergence
from repro.core.emd import kappas
from repro.exp import ExperimentSpec, Sweep, optimal_kappa2, \
    theorem1_comparison
from repro.fl.rounds import RunConfig
from repro.obs import Obs

SCENARIOS = ("highway_free_flow", "rush_hour", "urban_stop_go",
             "sparse_rural")


def analytic_claims() -> None:
    """The seed benchmark's closed-form claims (no training involved)."""
    p = convergence.ConvergenceParams(eta=0.01, varrho=10.0, mu=0.5, h=4,
                                      lambda_a=0.08)
    rhos = np.full(8, 1 / 8)
    t0 = time.perf_counter()
    for emd_bar in (0.4, 0.8, 1.2, 1.6):
        lams = np.full(8, emd_bar * 0.25)        # lambda_n = EMD_n * g_n
        k1, k2 = kappas(emd_bar)
        b_paper = convergence.bound(p, 200, rhos, lams, k1, k2)
        b_noaug = convergence.bound(p, 200, rhos, lams, 1.0, 0.0)
        k2_star, _ = optimal_kappa2(p, 200, rhos, lams)
        emit(f"theorem1/emd{emd_bar}", (time.perf_counter() - t0) * 1e6,
             f"bound_paper_k2={b_paper:.4f} bound_no_aug={b_noaug:.4f} "
             f"paper_beats_noaug={b_paper <= b_noaug + 1e-9} "
             f"k2_paper={k2:.3f} k2_grid_opt={k2_star:.2f}")


def run(rounds: int = 8, scenarios=SCENARIOS) -> None:
    analytic_claims()

    spec = ExperimentSpec(
        name="theorem1",
        strategies=("genfv", "fl_only"),
        scenarios=tuple(scenarios),
        base=RunConfig(rounds=rounds, train_size=600, test_size=64,
                       width_mult=0.125, model_bits=11.2e6 * 32),
    )
    fl_cfg = GenFVConfig(batch_size=16, local_steps=4, num_vehicles=10)
    # tracing is bitwise-neutral (tests/test_obs.py), so the traced sweep
    # IS the result sweep — no second untraced run needed
    obs = Obs(meta={"bench": "theorem1", "spec": spec.name,
                    "cells": spec.n_cells, "rounds": rounds})
    t0 = time.perf_counter()
    result = Sweep(spec, fl_cfg=fl_cfg, obs=obs).run()
    dt = (time.perf_counter() - t0) * 1e6 / spec.n_cells
    result.save()
    obs.save_metrics(spec.name)

    report = theorem1_comparison(result)
    report.save("theorem1")
    for row in report.per_scenario():
        emit(f"theorem1/bound_vs_realized/{row['scenario']}", dt,
             f"bound_T={row['bound_final']:.4f} "
             f"realized_T={row['realized_final']:.4f} "
             f"tightness={row['tightness']:.2f}x "
             f"valid={row['valid_fraction'] * 100:.0f}% "
             f"emd_bar={row['emd_bar']:.2f}")
    emit("theorem1/sweep", dt,
         f"cells={spec.n_cells} "
         f"batched_dispatches={result.meta['planner_dispatches']} "
         f"largest_batch={result.meta['planner_largest_batch']}")


if __name__ == "__main__":
    run()

"""Shared benchmark helpers: the house CSV line, the unified
``repro.obs/bench/v1`` JSON envelope, and the obs stopwatch.

Every BENCH_*.json is assembled by `record()` so downstream readers
(`make_experiments_md.py`, tests/test_bench_schema.py) see one shape:
``schema / bench / quick / host / config / results`` — with each module's
historical top-level keys kept as aliases, so pre-existing consumers of
e.g. ``doc["throughput"]`` keep working.

Timing uses `repro.obs.stopwatch` (an explicit-clock context manager),
which replaced the old `timer()` here — that helper returned a raw
``time.perf_counter()`` float despite its name suggesting a context, and
had no call sites left.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

from repro.obs import BENCH_SCHEMA, Stopwatch, host_meta, stopwatch

__all__ = ["ART", "BENCH_SCHEMA", "Stopwatch", "emit", "ensure_art",
           "host_meta", "record", "stopwatch", "write_json"]

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def ensure_art():
    os.makedirs(ART, exist_ok=True)
    return ART


def record(bench: str, *, quick: bool = False,
           config: Optional[Dict[str, Any]] = None,
           results: Any = None, obs=None, **legacy) -> Dict[str, Any]:
    """Build the unified benchmark envelope.

    `legacy` keys are merged at top level (aliases for each module's
    historical schema); an enabled `obs` contributes its metrics snapshot
    under ``"metrics"``.
    """
    doc: Dict[str, Any] = {
        "schema": BENCH_SCHEMA,
        "bench": bench,
        "quick": bool(quick),
        "host": host_meta(),
        "config": dict(config) if config else {},
        "results": results if results is not None else {},
    }
    if obs is not None and getattr(obs, "enabled", False):
        doc["metrics"] = obs.metrics.payload()
    for k, v in legacy.items():
        doc.setdefault(k, v)
    return doc


def write_json(doc: Dict[str, Any], path: str, indent: int = 2) -> str:
    with open(path, "w") as f:
        json.dump(doc, f, indent=indent)
        f.write("\n")
    return path

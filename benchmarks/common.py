"""Shared benchmark helpers: CSV emission + timing."""
from __future__ import annotations

import os
import time

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def timer():
    return time.perf_counter()


def ensure_art():
    os.makedirs(ART, exist_ok=True)
    return ART

"""repro.exp sweep benchmark: batched grid execution vs per-cell runners.

Runs the same strategy x scenario grid twice — once through `Sweep.run()`
(shared datasets/engines, one batched SUBP2-4 dispatch per planning group
per round) and once as independent `GenFVRunner.train()` calls — verifies
the curves agree bitwise (the executor's core guarantee), and reports the
wall-clock ratio plus the sharing counters.

  PYTHONPATH=src python -m benchmarks.bench_sweep [--quick] [--out PATH]

Writes BENCH_sweep.json (default: repo root) and prints the house
``name,us_per_call,derived`` CSV lines. --quick shrinks to a 2-cell x
2-round grid on a tiny train set (tier-1: tests/test_exp.py).
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl.rounds import GenFVRunner, RunConfig
from repro.obs import Obs

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_sweep.json")


def make_spec(quick: bool) -> ExperimentSpec:
    if quick:
        return ExperimentSpec(
            name="bench_sweep_quick",
            strategies=("genfv", "fl_only"),
            scenarios=("rush_hour",),
            base=RunConfig(rounds=2, train_size=300, test_size=32,
                           width_mult=0.0625))
    return ExperimentSpec(
        name="bench_sweep",
        strategies=("genfv", "fedavg", "no_emd", "fl_only"),
        scenarios=("highway_free_flow", "rush_hour"),
        seeds=(0, 1),
        base=RunConfig(rounds=6, train_size=600, test_size=64,
                       width_mult=0.0625))


def fl_cfg(quick: bool) -> GenFVConfig:
    return GenFVConfig(batch_size=8, local_steps=2,
                       num_vehicles=6 if quick else 10)


def run(quick: bool = True, out: str | None = None) -> dict:
    spec = make_spec(quick)
    cfg = fl_cfg(quick)
    cells = spec.expand()

    # warmup: one throwaway sweep compiles every jit bucket both paths use
    Sweep(spec, fl_cfg=cfg).run()

    # the measured sweep carries a tracer: per-phase span distributions land
    # in the envelope's "metrics" block, and attaching it must not perturb
    # the run (the bitwise-parity check below still holds)
    obs = Obs(meta={"bench": "sweep", "spec": spec.name})
    with stopwatch() as sw:
        result = Sweep(spec, fl_cfg=cfg, obs=obs).run()
    t_sweep = sw.elapsed_s

    with stopwatch() as sw:
        singles = [GenFVRunner(c.run, fl_cfg=cfg).train() for c in cells]
    t_single = sw.elapsed_s

    mismatches = 0
    for c, single in zip(cells, singles):
        for key in ("loss", "accuracy", "t_bar"):
            if not np.array_equal(result.metrics[key][c.index],
                                  single.curve(key)):
                mismatches += 1
    speedup = t_single / t_sweep

    emit(f"sweep/{'quick' if quick else 'full'}_grid",
         t_sweep * 1e6 / spec.n_cells,
         f"cells={spec.n_cells} speedup={speedup:.2f}x "
         f"bitwise_parity={mismatches == 0} "
         f"dispatches={result.meta['planner_dispatches']} "
         f"largest_batch={result.meta['planner_largest_batch']} "
         f"dataset_builds={result.meta['dataset_builds']}")

    results = {
        "n_cells": spec.n_cells,
        "rounds": cells[0].run.rounds,
        "t_sweep_s": t_sweep,
        "t_single_s": t_single,
        "speedup": speedup,
        "bitwise_parity": mismatches == 0,
        "meta": result.meta,
    }
    doc = record("repro.exp sweep vs per-cell runners", quick=quick,
                 results=results, obs=obs, **results)
    write_json(doc, out or DEFAULT_OUT, indent=1)
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    doc = run(quick=args.quick, out=args.out)
    return 0 if doc["bitwise_parity"] else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Benchmark driver: one module per paper table/figure + the roofline
reader. Prints ``name,us_per_call,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig5,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import stopwatch
from benchmarks import (bench_faults, bench_gen, bench_planner,
                        bench_rounds, bench_stream, bench_sweep,
                        bench_world, fig5_emd, fig6_selection, fig7_power,
                        fig8_subproblems, fig9_generation, fig10_noniid,
                        roofline, theorem1)

MODULES = {
    "fig5": fig5_emd.run,
    "fig6": fig6_selection.run,
    "fig7": fig7_power.run,
    "fig8": fig8_subproblems.run,
    "fig9": fig9_generation.run,
    "fig10": fig10_noniid.run,
    "theorem1": theorem1.run,
    "roofline": roofline.run,
    "rounds": bench_rounds.run,          # quick sweep; full: -m benchmarks.bench_rounds
    "world": bench_world.run,            # sim world; full: -m benchmarks.bench_world
    "planner": bench_planner.run,        # two-scale planner; full: -m benchmarks.bench_planner
    "sweep": bench_sweep.run,            # repro.exp grid; full: -m benchmarks.bench_sweep
    "faults": bench_faults.run,          # fault schedules; full: -m benchmarks.bench_faults
    "stream": bench_stream.run,          # quorum streaming; full: -m benchmarks.bench_stream
    "gen": bench_gen.run,                # AIGC dataplane; full: -m benchmarks.bench_gen
}

# FL-training-heavy modules skipped under --quick (the `sweep` smoke still
# exercises the grid/batched-planning path end-to-end there)
HEAVY = ("fig6", "fig10", "theorem1")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys")
    ap.add_argument("--quick", action="store_true",
                    help=f"skip the FL-training figures {HEAVY}")
    args = ap.parse_args()

    keys = list(MODULES)
    if args.only:
        keys = [k for k in args.only.split(",") if k in MODULES]
    if args.quick:
        keys = [k for k in keys if k not in HEAVY]

    print("name,us_per_call,derived")
    failures = 0
    for k in keys:
        with stopwatch() as sw:
            try:
                MODULES[k]()
            except Exception as e:
                failures += 1
                print(f"{k}/FAILED,0.00,{type(e).__name__}: {e}")
                traceback.print_exc(file=sys.stderr)
        print(f"{k}/module_total,{sw.elapsed_s * 1e6:.0f},")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

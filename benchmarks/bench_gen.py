"""AIGC dataplane benchmark: batched diffusion sampling at fleet scale.

Four measurements of `repro.gen` (DESIGN.md §"AIGC dataplane"):

* **throughput** — samples/sec of the bucketed jitted dispatch across the
  (bucket, sampler_steps) grid, steady-state (compile excluded);
* **batched vs sequential** — serving one eq.-48 round schedule (K=16
  selected vehicles) as ONE fused dispatch vs the per-vehicle reference
  paths: `per_label` (a dispatch per (vehicle, label) group — the loop the
  parity tests pin the fused sampler against) and `per_vehicle` (one
  dispatch per vehicle schedule). The headline ``speedup`` is fused vs
  per_label;
* **crossover** — measured per-image latency t0(steps) against the FL
  round window: the largest sampler_steps at which a b-image schedule
  still fits inside t_bar = t_max (compute-bound generation vs comm-bound
  FL);
* **accuracy vs steps** — the headline quality/cost curve: a
  `sampler_steps`-axis sweep of `RunConfig(generator="ddpm")` under
  `urban_stop_go` (full mode only; the sweep exercises the measured-t0
  planner coupling end to end).

  PYTHONPATH=src python -m benchmarks.bench_gen [--quick] [--out PATH]

Writes BENCH_gen.json (default: repo root) plus the steps-sweep artifact
``artifacts/bench_gen.stepsweep.json`` rendered into EXPERIMENTS.md
§Generation. --quick shrinks to a tiny model + pretrain budget (tier-1:
tests/test_gen.py smokes it).
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import emit, record, stopwatch, write_json
import repro.gen.service as gen_service
from repro.configs.base import GenFVConfig
from repro.core.generation import label_schedule
from repro.exp import ExperimentSpec, Sweep
from repro.exp.artifacts import save_artifact
from repro.fl.rounds import RunConfig
from repro.gen.sampler import sample_schedule
from repro.gen.service import gen_round_key

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(REPO_ROOT, "BENCH_gen.json")

#: the acceptance scenario: a K=16-vehicle round schedule
K_VEHICLES = 16
REPEATS = 3


def _grid(quick: bool):
    if quick:
        return (4, 16), (2, 4)
    return (4, 16, 64), (10, 25, 50)


def _model(quick: bool):
    """(params, ddpm) of the serving model: tiny budget under --quick, the
    runner's pretrained foundation model otherwise (in-process lru share
    with any later sweep cells)."""
    if quick:
        return gen_service._pretrained_params("cifar10", 10, 8, 8, 2, 64, 0)
    return gen_service._pretrained_params(
        "cifar10", 10, gen_service.RUNNER_TIMESTEPS,
        gen_service.RUNNER_BASE_WIDTH, gen_service.PRETRAIN_STEPS,
        gen_service.PRETRAIN_REF, gen_service.PRETRAIN_SEED)


def _best_of(fn, repeats: int = REPEATS) -> float:
    fn()                                     # warmup: compile + caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_throughput(params, ddpm, buckets, steps_grid) -> list:
    key = gen_round_key(0, 0)
    rows = []
    for steps in steps_grid:
        for bucket in buckets:
            labels = [i % ddpm.num_classes for i in range(bucket)]
            t = _best_of(lambda: sample_schedule(params, ddpm, key, labels,
                                                 steps))
            rows.append({"bucket": bucket, "sampler_steps": steps,
                         "wall_s": t, "samples_per_s": bucket / t,
                         "t_per_image_s": t / bucket})
            emit(f"gen/throughput/b{bucket}_s{steps}", t * 1e6,
                 f"{bucket / t:.2f} samples/s")
    return rows


def bench_batched_vs_sequential(params, ddpm, steps_list) -> dict:
    """One round's eq.-48 schedules across K=16 vehicles, served three ways.

    Each selected vehicle gets its own `label_schedule(b_i, C)` (b_i = 4:
    the b*~4K regime of the paper-assumed t0 = 0.05s, where eq. 48 yields
    b* ~ 50 inside a 3 s window). The fused path serves the concatenation
    of all K schedules in ONE bucketed dispatch; `per_vehicle` dispatches
    once per vehicle schedule; `per_label` — the reference loop the parity
    tests pin the fused sampler against — dispatches once per (vehicle,
    label-group), which for b_i=4 spread over C=10 classes means singleton
    groups padded to the bucket floor. All paths are steady-state (warmed)
    and produce bitwise-identical images, so this is purely a wall-clock
    comparison of dispatch structure.

    The headline ``speedup`` is taken at the SMALLEST measured stride: the
    crossover table shows high strides cannot meet the comm-bound round
    window at this b*, so the low-stride row is the config the dataplane
    actually serves (higher-stride rows are reported alongside).
    """
    per_vehicle = 4
    b_star = K_VEHICLES * per_vehicle
    key = gen_round_key(0, 1)
    # vehicle n's schedule, label groups rotated by n so the fleet covers
    # all classes; with b_i < C every group is a singleton
    shards = []
    labels_all = []
    for n in range(K_VEHICLES):
        counts = label_schedule(per_vehicle, ddpm.num_classes)
        lab = (np.repeat(np.arange(ddpm.num_classes), counts) + n) \
            % ddpm.num_classes
        shards.append((n * per_vehicle, lab.astype(np.int32)))
        labels_all.append(lab)
    labels = np.concatenate(labels_all).astype(np.int32)

    rows = []
    for steps in steps_list:
        t_fused = _best_of(lambda: sample_schedule(params, ddpm, key,
                                                   labels, steps))

        def seq_per_vehicle():
            for start, lab in shards:
                sample_schedule(params, ddpm, key, lab, steps, start=start)

        def seq_per_label():
            for start, lab in shards:
                for j, c in enumerate(lab):
                    sample_schedule(params, ddpm, key, [int(c)], steps,
                                    start=start + j)

        t_vehicle = _best_of(seq_per_vehicle, repeats=1)
        t_label = _best_of(seq_per_label, repeats=1)
        row = {
            "k_vehicles": K_VEHICLES, "b_star": b_star,
            "sampler_steps": steps,
            "wall_s_batched": t_fused,
            "wall_s_per_vehicle": t_vehicle,
            "wall_s_per_label": t_label,
            "speedup": t_label / t_fused,
            "speedup_vs_per_vehicle": t_vehicle / t_fused,
        }
        rows.append(row)
        emit(f"gen/batched_vs_seq/K{K_VEHICLES}_s{steps}", t_fused * 1e6,
             f"x{row['speedup']:.2f} per-label, "
             f"x{row['speedup_vs_per_vehicle']:.2f} per-vehicle")
    head = rows[0]
    return {
        "k_vehicles": K_VEHICLES, "b_star": b_star,
        "sampler_steps": head["sampler_steps"],
        "speedup": head["speedup"],
        "speedup_vs_per_vehicle": head["speedup_vs_per_vehicle"],
        "rows": rows,
    }


def bench_crossover(params, ddpm, steps_grid, t_bar: float,
                    b_schedule: int = 32) -> dict:
    """Measured t0(steps) against the comm-bound round window t_bar: the
    generation window eq. 48 actually prices. Generation is compute-bound
    once b * t0(steps) exceeds the window."""
    key = gen_round_key(0, 2)
    bucket = 16
    labels = [i % ddpm.num_classes for i in range(bucket)]
    rows = []
    for steps in steps_grid:
        t = _best_of(lambda: sample_schedule(params, ddpm, key, labels,
                                             steps), repeats=2)
        t0 = t / bucket
        rows.append({"sampler_steps": steps, "t_per_image_s": t0,
                     "gen_wall_s": b_schedule * t0,
                     "fits_round_window": bool(b_schedule * t0 <= t_bar)})
    fitting = [r["sampler_steps"] for r in rows if r["fits_round_window"]]
    cross = {"t_bar_s": t_bar, "b_schedule": b_schedule, "points": rows,
             "max_steps_within_window": max(fitting) if fitting else 0}
    emit("gen/crossover", 0.0,
         f"comm-bound up to steps={cross['max_steps_within_window']} "
         f"(b={b_schedule}, t_bar={t_bar}s)")
    return cross


def bench_accuracy_vs_steps(steps_axis) -> dict:
    """sampler_steps sweep of the real dataplane under urban_stop_go: the
    ExperimentSpec axis + measured-t0 planner coupling, end to end."""
    spec = ExperimentSpec(
        name="gen_steps",
        sampler_steps=tuple(steps_axis),
        base=RunConfig(strategy="genfv", scenario="urban_stop_go",
                       generator="ddpm", rounds=3, train_size=600,
                       test_size=64, width_mult=0.0625, seed=0))
    cfg = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=8)
    res = Sweep(spec, fl_cfg=cfg).run()
    rows = []
    for i, cell in enumerate(res.cells):
        acc = res.metrics["accuracy"][i]
        rows.append({"sampler_steps": cell["sampler_steps"],
                     "final_accuracy": float(acc[~np.isnan(acc)][-1]),
                     "accuracy_curve": [float(a) for a in acc
                                        if not np.isnan(a)],
                     "b_gen_total": int(np.nansum(res.metrics["b_gen"][i]))})
        emit(f"gen/acc_steps/s{cell['sampler_steps']}", 0.0,
             f"acc={rows[-1]['final_accuracy']:.3f} "
             f"b={rows[-1]['b_gen_total']}")
    return {"scenario": "urban_stop_go", "rounds": 3, "cells": rows}


def run(quick: bool = True, out: str | None = None) -> dict:
    buckets, steps_grid = _grid(quick)
    sw = stopwatch()
    params, ddpm = _model(quick)

    throughput = bench_throughput(params, ddpm, buckets, steps_grid)
    # deployable-stride first (headline), largest stride alongside
    batched = bench_batched_vs_sequential(
        params, ddpm, (steps_grid[0], steps_grid[-1]))
    crossover = bench_crossover(params, ddpm, steps_grid,
                                t_bar=GenFVConfig().t_max,
                                b_schedule=batched["b_star"])

    acc = None
    if not quick:
        acc = bench_accuracy_vs_steps((5, 20, 50))
        save_artifact("bench_gen", "stepsweep",
                      {"bench": "gen", "accuracy_vs_steps": acc,
                       "crossover": crossover})

    results = {"throughput": throughput,
               "batched_vs_sequential": batched,
               "crossover": crossover,
               "accuracy_vs_steps": acc}
    doc = record("AIGC dataplane: batched DDPM sampling (repro.gen)",
                 quick=quick,
                 config={"model": {"timesteps": ddpm.timesteps,
                                   "base_width": ddpm.base_width,
                                   "num_classes": ddpm.num_classes},
                         "buckets": list(buckets),
                         "steps_grid": list(steps_grid),
                         "k_vehicles": K_VEHICLES},
                 results=results, wall_s=sw.elapsed_s,
                 speedup=batched["speedup"])
    write_json(doc, out or DEFAULT_OUT, indent=1)
    return doc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    doc = run(quick=args.quick, out=args.out)
    return 0 if doc["speedup"] > 1.0 else 1


if __name__ == "__main__":
    raise SystemExit(main())

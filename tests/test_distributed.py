"""Distribution layer: sharding rules (divisibility invariants, property
based) and the GenFV weighted all-reduce (runs in a subprocess with 8 fake
host devices so the main test process keeps 1 device)."""
import subprocess
import sys
import os

import jax
import numpy as np
import pytest

try:  # hypothesis is optional in the image; only the property sweep needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.distributed.sharding import shard_leaf


class _FakeMesh:
    """Duck-typed mesh exposing .shape for the pure sharding rules."""
    def __init__(self, shape):
        self.shape = shape


if HAVE_HYPOTHESIS:
    @given(st.lists(st.integers(1, 4096), min_size=1, max_size=4),
           st.sampled_from([(16, 16), (2, 16, 16), (4, 2)]))
    @settings(max_examples=200, deadline=None)
    def test_shard_leaf_divisibility(shape, mesh_dims):
        if len(mesh_dims) == 3:
            mesh = _FakeMesh({"pod": mesh_dims[0], "data": mesh_dims[1],
                              "model": mesh_dims[2]})
        else:
            mesh = _FakeMesh({"data": mesh_dims[0], "model": mesh_dims[1]})
        spec = shard_leaf(shape, mesh)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert shape[dim] % size == 0, (shape, spec)
        # an axis name may appear at most once in the spec
        used = [a for ax in spec if ax is not None
                for a in (ax if isinstance(ax, tuple) else (ax,))]
        assert len(used) == len(set(used))
else:
    def test_shard_leaf_divisibility():
        pytest.skip("hypothesis not installed; property sweep skipped")


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import genfv_weighted_allreduce

mesh = jax.make_mesh((8,), ("data",))
n = 8
rng = np.random.default_rng(0)
models = {"w": jnp.asarray(rng.normal(size=(n, 4, 3)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}
weights = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
out = genfv_weighted_allreduce(models, weights, mesh, axes=("data",))
ref_w = np.tensordot(np.asarray(weights), np.asarray(models["w"]), axes=(0, 0))
ref_b = np.tensordot(np.asarray(weights), np.asarray(models["b"]), axes=(0, 0))
assert np.allclose(np.asarray(out["w"]), ref_w, atol=1e-5), "w mismatch"
assert np.allclose(np.asarray(out["b"]), ref_b, atol=1e-5), "b mismatch"
print("OK")
"""


def test_genfv_weighted_allreduce_subprocess():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env, capture_output=True,
        text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_main_process_single_device():
    """Tests and benches must see 1 device (dry-run flags are module-local)."""
    assert len(jax.devices()) == 1

"""Launch layer: input specs (ShapeDtypeStruct, no allocation), the
long-context skip policy, and the analytic roofline model wiring — all pure
eval_shape, independent of device count."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch import specs as S
from repro.launch.analysis import loop_trip_count, model_flops
from repro.optim import adamw, constant_schedule

OPT = adamw(constant_schedule(1e-4))


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_train_specs_structure(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    (p, o, b), kind = S.input_specs(cfg, shape, OPT)
    assert kind == "train"
    # everything is abstract — no arrays were allocated
    for leaf in jax.tree.leaves((p, o, b)):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert b["tokens"].shape[0] == shape.global_batch
    total_ctx = b["tokens"].shape[1] + (cfg.frontend_tokens
                                        if cfg.modality == "vision" else 0)
    assert total_ctx == shape.seq_len
    if cfg.modality == "audio":
        assert b["frames"].shape == (shape.global_batch, cfg.encoder_seq,
                                     cfg.d_model)
    # params specs match an actual reduced init's structure modulo sizes
    n_leaves = len(jax.tree.leaves(p))
    assert n_leaves > 4


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_decode_specs(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["decode_32k"]
    (p, c, t, pos), kind = S.input_specs(cfg, shape, OPT)
    assert kind == "decode"
    assert t.shape == (shape.global_batch, 1)
    assert pos.shape == (shape.global_batch, 1)
    # the cache must hold seq_len context (ring buffers may be smaller for
    # local layers but never larger)
    for leaf in jax.tree.leaves(c):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_long_context_policy():
    runnable = {a: S.runnable(get_config(a), INPUT_SHAPES["long_500k"])[0]
                for a in list_archs()}
    assert runnable["xlstm-1.3b"] and runnable["recurrentgemma-9b"]
    assert runnable["gemma2-9b"]        # documented local-window variant
    for a in ("minicpm-2b", "qwen1.5-0.5b", "gemma-2b", "grok-1-314b",
              "olmoe-1b-7b", "whisper-tiny", "llava-next-mistral-7b"):
        assert not runnable[a], a
    # exactly 3 archs run long_500k
    assert sum(runnable.values()) == 3


def test_model_flops_closed_form():
    cfg = get_config("qwen1.5-0.5b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    assert tr == 6.0 * cfg.active_param_count() * 256 * 4096
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert de == 2.0 * cfg.active_param_count() * 128
    moe_cfg = get_config("olmoe-1b-7b")
    assert moe_cfg.active_param_count() < 0.35 * moe_cfg.param_count()


def test_loop_trip_counts():
    assert loop_trip_count(get_config("qwen1.5-0.5b")) == 24
    assert loop_trip_count(get_config("gemma2-9b")) == 21     # (local,global)x21
    assert loop_trip_count(get_config("recurrentgemma-9b")) == 12  # + 2 rem
    assert loop_trip_count(get_config("xlstm-1.3b")) == 6     # groups of 8


def test_vlm_specs_carveout():
    cfg = get_config("llava-next-mistral-7b")
    shape = INPUT_SHAPES["prefill_32k"]
    (p, c, b), kind = S.input_specs(cfg, shape, OPT)
    assert "patch_embeds" in b
    assert b["patch_embeds"].shape == (32, 2880, 1024)
    assert b["tokens"].shape == (32, 32768 - 2880)

"""Assigned-architecture configs: exact sizes from the assignment table."""
import pytest

from repro.configs import get_config, list_archs, INPUT_SHAPES

ASSIGNED = {
    #                    L    d     H   kv  d_ff    vocab
    "minicpm-2b":        (40, 2304, 36, 36, 5760, 122753),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "gemma2-9b":         (42, 3584, 16, 8, 14336, 256000),
    "whisper-tiny":      (4, 384, 6, 6, 1536, 51865),
    "grok-1-314b":       (64, 6144, 48, 8, 32768, 131072),
    "gemma-2b":          (18, 2048, 8, 1, 16384, 256000),
    "xlstm-1.3b":        (48, 2048, 4, 4, 0, 50304),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "qwen1.5-0.5b":      (24, 1024, 16, 16, 2816, 151936),
    "olmoe-1b-7b":       (16, 2048, 16, 16, 1024, 50304),
}


def test_all_archs_listed():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_assigned_sizes(arch):
    cfg = get_config(arch)
    L, d, H, kv, dff, vocab = ASSIGNED[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == dff
    assert cfg.vocab_size == vocab
    assert cfg.citation


def test_moe_configs():
    grok = get_config("grok-1-314b")
    assert grok.moe.num_experts == 8 and grok.moe.experts_per_token == 2
    olmoe = get_config("olmoe-1b-7b")
    assert olmoe.moe.num_experts == 64 and olmoe.moe.experts_per_token == 8


def test_special_features():
    assert get_config("qwen1.5-0.5b").qkv_bias
    assert get_config("gemma2-9b").attn_softcap == 50.0
    assert get_config("gemma2-9b").pattern == ("local", "global")
    assert get_config("gemma-2b").num_kv_heads == 1            # MQA
    assert get_config("minicpm-2b").schedule == "wsd"
    assert get_config("whisper-tiny").encoder_layers == 4
    assert get_config("llava-next-mistral-7b").frontend_tokens == 2880
    assert get_config("recurrentgemma-9b").pattern == ("rglru", "rglru", "local")


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_variants(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    # reduced keeps every distinct block kind of the family
    assert set(cfg.pattern) == set(get_config(arch).pattern)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_counts_sane(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {"minicpm-2b": 2.7e9, "llava-next-mistral-7b": 7.3e9,
                "gemma2-9b": 9.2e9, "whisper-tiny": 39e6,
                "grok-1-314b": 314e9, "gemma-2b": 2.5e9,
                "xlstm-1.3b": 1.3e9, "recurrentgemma-9b": 9.0e9,
                "qwen1.5-0.5b": 0.46e9, "olmoe-1b-7b": 6.9e9}[arch]
    assert 0.5 * expected < n < 2.0 * expected, (arch, n, expected)
    assert cfg.active_param_count() <= n

"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not in the image; property sweeps skip")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import GenFVConfig
from repro.core import convergence, emd, generation, mobility
from repro.core.bandwidth import solve_bandwidth
from repro.data.partition import dirichlet_partition

CFG = GenFVConfig()


@st.composite
def histograms(draw, max_classes=20):
    y = draw(st.integers(2, max_classes))
    raw = draw(st.lists(st.floats(0.0, 1.0), min_size=y, max_size=y))
    arr = np.asarray(raw) + 1e-9
    return arr / arr.sum()


@given(histograms())
@settings(max_examples=100, deadline=None)
def test_emd_bounds(p):
    y = p.shape[0]
    e = emd.emd(p)
    assert -1e-9 <= e <= 2 * (y - 1) / y + 1e-9


@given(histograms())
@settings(max_examples=50, deadline=None)
def test_emd_triangle_vs_pair(p):
    """EMD to uniform == L1 distance; symmetric and zero iff equal."""
    u = np.full_like(p, 1.0 / p.shape[0])
    assert emd.emd(p) == emd.emd(u, p)
    assert emd.emd(p, p) == 0.0


@given(st.floats(0.0, 2.0))
@settings(max_examples=100, deadline=None)
def test_kappas_partition_of_unity(e):
    k1, k2 = emd.kappas(e)
    assert 0.0 <= k2 <= 1.0 and abs(k1 + k2 - 1.0) < 1e-12
    # monotone: worse heterogeneity -> more AIGC weight
    k1b, k2b = emd.kappas(min(e + 0.1, 2.0))
    assert k2b >= k2 - 1e-12


@given(st.lists(st.integers(1, 10_000), min_size=1, max_size=20))
@settings(max_examples=100, deadline=None)
def test_data_weights_simplex(sizes):
    rho = emd.data_weights(sizes)
    assert abs(rho.sum() - 1.0) < 1e-9
    assert np.all(rho >= 0)
    order = np.argsort(sizes)
    assert np.all(np.diff(rho[order]) >= -1e-12)   # bigger data -> bigger rho


@given(st.integers(2, 40), st.floats(0.05, 5.0), st.integers(1, 6))
@settings(max_examples=20, deadline=None)
def test_dirichlet_partition_exact_cover(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=600)
    parts = dirichlet_partition(labels, n_clients, alpha, rng, min_size=0)
    allidx = np.concatenate(parts) if parts else np.array([])
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)   # disjoint exact cover


@given(st.floats(-400.0, 400.0), st.floats(5.0, 120.0), st.integers(0, 1))
@settings(max_examples=100, deadline=None)
def test_holding_time_nonnegative(x, speed, direction):
    half = mobility.coverage_half_length(CFG)
    x = max(min(x, half), -half)
    v = speed if direction else -speed
    t = mobility.holding_time(CFG, x, v)
    assert t >= 0.0
    # remaining distance shrinks as the vehicle advances along its direction
    s1 = mobility.remaining_distance(CFG, x, v)
    step = np.sign(v) * 1.0
    if -half <= x + step <= half:
        s2 = mobility.remaining_distance(CFG, x + step, v)
        assert s2 <= s1


@given(st.integers(0, 5000), st.integers(2, 200))
@settings(max_examples=100, deadline=None)
def test_label_schedule_total_and_balance(b, y):
    c = generation.label_schedule(b, y)
    assert c.sum() == b and c.max() - c.min() <= 1


@given(st.integers(1, 12), st.integers(2, 50), st.floats(0.01, 0.09))
@settings(max_examples=30, deadline=None)
def test_theorem1_monotone_in_T(n, T, eta):
    p = convergence.ConvergenceParams(eta=eta)
    rhos = np.full(n, 1.0 / n)
    lams = np.linspace(0.05, 0.3, n)
    b1 = convergence.bound(p, T, rhos, lams, 0.8, 0.2)
    b2 = convergence.bound(p, T + 1, rhos, lams, 0.8, 0.2)
    assert b2 <= b1 + 1e-9


@given(st.integers(1, 10), st.integers(2, 30))
@settings(max_examples=30, deadline=None)
def test_bandwidth_solver_feasible(seed, n):
    rng = np.random.default_rng(seed)
    A = rng.uniform(0.2, 1.0, n)
    B = rng.uniform(0.5, 4.0, n)
    C = np.zeros(n)
    D = 0.3 * B
    M = float(n)
    res = solve_bandwidth(A, B, C, D, M, e_bar=50.0)
    assert res.l.shape == (n,)
    assert np.all(res.l > 0)
    assert res.l.sum() <= M * 1.01
    assert np.isfinite(res.t_bar)


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint import restore_into, restore_tree, save_tree
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": [np.ones(4), (np.zeros(2), np.full(3, 7.0))],
            "c": {"d": np.int32(3) * np.ones(1, np.int32)}}
    path = str(tmp_path / "ckpt.npz")
    save_tree(path, tree, metadata={"step": 12})
    back = restore_tree(path)
    for l1, l2 in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    into = restore_into(tree, path)
    assert jax.tree.structure(into) == jax.tree.structure(tree)


@given(st.integers(1, 8), st.integers(1, 60), st.integers(1, 6))
@settings(max_examples=25, deadline=None)
def test_lru_scan_matches_naive(b, s, w):
    import jax.numpy as jnp
    from repro.models.rglru import lru_scan
    rng = np.random.default_rng(b * s + w)
    la = jnp.asarray(-np.abs(rng.normal(size=(b, s, w))), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(b, s, w)), jnp.float32)
    out = lru_scan(la, bb)
    h = np.zeros((b, w), np.float64)
    for t in range(s):
        h = np.exp(np.asarray(la[:, t], np.float64)) * h + np.asarray(bb[:, t], np.float64)
        np.testing.assert_allclose(np.asarray(out[:, t]), h, rtol=2e-4,
                                   atol=2e-5)

"""Streaming round engine (fl/stream.py): StreamConfig validation and
RunConfig wiring, bitwise sync parity at full quorum, quorum-commit /
degradation-ladder semantics under faults, replay + cross-planner
determinism, mid-stream golden checkpoint resume (in-flight uploads and
the virtual clock survive), checkpoint loader cross-refusal, no-stall
coverage over every registered fault preset, and the bench smoke."""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs.base import GenFVConfig, StreamConfig
from repro.fl.faults import FaultSpec, fault_names
from repro.fl.rounds import GenFVRunner, RunConfig, run_payload
from repro.fl.stream import StreamEngine
from repro.obs import Obs, VirtualClock

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

FAST = dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
FAST5 = dict(rounds=5, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)


def _params_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        (np.asarray(x) == np.asarray(y)).all() for x, y in zip(la, lb))


def _stream(run, sc=None, **kw):
    runner = GenFVRunner(run, FAST_CFG, **kw)
    return runner, StreamEngine(runner, sc)


# ---------------------------------------------------------------------------
# Config surface
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw,fragment", [
    (dict(quorum=0.0), "quorum"),
    (dict(quorum=1.5), "quorum"),
    (dict(cadence_s=-1.0), "cadence_s"),
    (dict(deadline_slack=-0.1), "deadline_slack"),
    (dict(retry_budget=-1), "retry_budget"),
    (dict(retry_backoff_s=0.0), "retry_backoff_s"),
    (dict(retry_backoff_cap_s=0.1), "retry_backoff_cap_s"),
    (dict(staleness_discount=0.0), "staleness_discount"),
    (dict(max_staleness=-1), "max_staleness"),
])
def test_stream_config_validation(kw, fragment):
    with pytest.raises(ValueError, match=fragment):
        StreamConfig(**kw)


def test_stream_config_payload_roundtrip():
    sc = StreamConfig(quorum=0.6, cadence_s=2.0, retry_budget=3)
    assert StreamConfig.from_payload(sc.to_payload()) == sc


def test_runconfig_stream_coercion_and_payload():
    # a plain dict (JSON payload) coerces to StreamConfig at construction
    run = RunConfig(stream={"quorum": 0.5, "retry_budget": 1}, **FAST)
    assert isinstance(run.stream, StreamConfig)
    assert run.stream.quorum == 0.5 and run.stream.retry_budget == 1
    # run_payload flattens it back out and the round-trip is exact
    rp = run_payload(run)
    assert isinstance(rp["stream"], dict)
    assert RunConfig(**rp) == run
    assert run_payload(RunConfig(**rp)) == rp
    # None stays None
    assert run_payload(RunConfig(**FAST))["stream"] is None


def test_virtual_clock():
    clk = VirtualClock(2.0)
    assert clk() == 2.0
    assert clk.advance(1.5) == 3.5
    with pytest.raises(ValueError, match="backwards"):
        clk.advance(-0.1)


def test_engine_rejects_unstreamable_configs():
    with pytest.raises(ValueError, match="vectorized"):
        _stream(RunConfig(vectorized=False, **FAST))
    with pytest.raises(ValueError, match="aigc_only"):
        _stream(RunConfig(strategy="aigc_only", **FAST))


# ---------------------------------------------------------------------------
# Sync parity: full quorum, no faults, cadence off => bitwise-equal to the
# synchronous GenFVRunner loop (same RoundLogs, same final params).
# ---------------------------------------------------------------------------
def test_clean_full_quorum_is_bitwise_sync():
    run = RunConfig(seed=0, **FAST)
    sync = GenFVRunner(run, FAST_CFG)
    res_sync = sync.train()
    runner, eng = _stream(run)          # defaults: quorum=1.0, cadence=0
    res_stream = eng.run()
    assert res_sync.logs == res_stream.logs
    assert _params_equal(sync.server.params, runner.server.params)
    # every commit is a healthy rung-0 quorum landing exactly on t_bar
    assert all(s.rung == 0 for s in eng.slogs)
    for s, l in zip(eng.slogs, res_sync.logs):
        assert s.t_commit - s.t_start == pytest.approx(l.t_round)


def test_quorum_commits_early_and_merges_late_arrivals():
    run = RunConfig(seed=0, **FAST5)
    runner, eng = _stream(run, StreamConfig(quorum=0.4))
    res = eng.run()
    ks = [l.selected + l.dropped + l.late for l in res.logs]
    # the quorum commit fires strictly before the straggler window when
    # q < K arrivals suffice
    early = [s for s, l, k in zip(eng.slogs, res.logs, ks)
             if k and s.quorum_target < k]
    assert early and all(s.rung == 0 for s in eng.slogs)
    assert any(s.t_commit - s.t_start < l.t_bar - 1e-12
               for s, l in zip(eng.slogs, res.logs) if l.t_bar > 0)
    # post-commit uploads are not lost: they re-enter as in-flight merges
    late_total = sum(s.late for s in eng.slogs)
    landed = sum(s.merged_inflight + s.gap_merged for s in eng.slogs) \
        + len(eng.inflight) + sum(s.stale_dropped for s in eng.slogs)
    assert late_total > 0 and landed == late_total


# ---------------------------------------------------------------------------
# Determinism: replay + cross-planner parity with faults and retries live.
# ---------------------------------------------------------------------------
def _churn_run(planner):
    run = RunConfig(seed=0, planner=planner, faults="rush_hour_deep_fade",
                    **FAST5)
    runner, eng = _stream(run, StreamConfig(quorum=0.6, cadence_s=0.1,
                                            retry_budget=2))
    return runner, eng, eng.run()


def test_streaming_replay_determinism():
    _, e1, r1 = _churn_run("jax")
    _, e2, r2 = _churn_run("jax")
    assert r1.logs == r2.logs
    assert e1.slogs == e2.slogs
    assert [(f.due, f.seq, f.vid) for f in e1.inflight] == \
        [(f.due, f.seq, f.vid) for f in e2.inflight]


def test_cross_planner_commit_and_params_parity():
    rj, ej, resj = _churn_run("jax")
    rn, en, resn = _churn_run("numpy")
    assert ej.slogs == en.slogs          # identical commit sequence
    assert resj.logs == resn.logs
    assert _params_equal(rj.server.params, rn.server.params)
    # the schedule actually exercised the machinery under test
    assert sum(s.retries for s in ej.slogs) > 0
    assert any(s.rung > 0 for s in ej.slogs)
    assert sum(s.merged_inflight + s.gap_merged for s in ej.slogs) > 0


# ---------------------------------------------------------------------------
# Mid-stream golden resume: in-flight uploads, the event queue and the
# virtual clock all survive a checkpoint bitwise.
# ---------------------------------------------------------------------------
def test_midstream_checkpoint_resume_golden(tmp_path):
    run = RunConfig(seed=0, faults="rush_hour_deep_fade", **FAST5)
    sc = StreamConfig(quorum=0.6, cadence_s=0.1, retry_budget=2)
    r_full, e_full = _stream(run, sc)
    res_full = e_full.run()

    r_head, e_head = _stream(run, sc)
    for t in range(3):
        e_head.run_round(t)
    assert e_head.inflight          # the checkpoint carries live uploads
    path = e_head.save_checkpoint(str(tmp_path / "stream_ck"))

    r_res, e_res = _stream(run, sc)
    assert e_res.load_checkpoint(path) == 3
    assert e_res.now == e_head.now
    assert [(f.due, f.seq, f.vid, f.round) for f in e_res.inflight] == \
        [(f.due, f.seq, f.vid, f.round) for f in e_head.inflight]
    res_res = e_res.run()
    assert res_full.logs == res_res.logs
    assert e_full.slogs == e_res.slogs
    assert _params_equal(r_full.server.params, r_res.server.params)


def test_checkpoint_loader_cross_refusal(tmp_path):
    run = RunConfig(seed=0, **FAST)
    # streaming checkpoint refused by the synchronous loader
    r1, e1 = _stream(run)
    e1.run_round(0)
    spath = e1.save_checkpoint(str(tmp_path / "s"))
    r2 = GenFVRunner(run, FAST_CFG)
    with pytest.raises(ValueError, match="streaming engine"):
        r2.load_checkpoint(spath)
    # synchronous checkpoint refused by the streaming loader
    r3 = GenFVRunner(run, FAST_CFG)
    r3.run_round(0)
    kpath = r3.save_checkpoint(str(tmp_path / "k"))
    _, e4 = _stream(run)
    with pytest.raises(ValueError, match="synchronous runner"):
        e4.load_checkpoint(kpath)
    # a different streaming policy is a different run
    _, e5 = _stream(run, StreamConfig(quorum=0.5))
    with pytest.raises(ValueError, match="different streaming policy"):
        e5.load_checkpoint(spath)


# ---------------------------------------------------------------------------
# Liveness: no hang or round stall at any registered fault preset, and the
# ladder + ledger stay coherent.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("preset", sorted(fault_names()))
def test_no_stall_under_any_preset(preset):
    run = RunConfig(seed=0, faults=preset, **FAST)
    runner, eng = _stream(run, StreamConfig(quorum=0.6, retry_budget=1))
    res = eng.run()
    assert len(res.logs) == FAST["rounds"]          # every round committed
    starts = [s.t_start for s in eng.slogs]
    assert all(b > a for a, b in zip(starts, starts[1:]))   # clock advances
    assert eng.now > starts[-1]
    for s in eng.slogs:
        assert 0 <= s.rung <= 3
        assert s.t_commit >= s.t_start
        assert s.arrived >= (1 if s.rung in (0, 1, 2) and s.quorum_target
                             else 0)


def test_stream_ledger_reaches_obs():
    obs = Obs(clock=VirtualClock())
    run = RunConfig(seed=0, faults="rush_hour_deep_fade", obs=obs, **FAST)
    runner, eng = _stream(run, StreamConfig(quorum=0.6))
    eng.run()
    m = obs.metrics
    assert m.counter_value("stream/rounds") == FAST["rounds"]
    assert m.counter_value("stream/retries") == \
        sum(s.retries for s in eng.slogs)
    assert m.gauge_value("stream/inflight") == len(eng.inflight)
    names = {e["name"] for e in obs.events}
    assert {"stream/tick", "stream/retry", "stream/commit"} <= names


# ---------------------------------------------------------------------------
# Bench smoke (tier-1 wiring, mirroring bench_faults --quick)
# ---------------------------------------------------------------------------
def test_bench_stream_quick_smoke(tmp_path):
    out = tmp_path / "BENCH_stream.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_stream", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["quick"] is True
    assert data["deterministic"] is True
    names = [row["faults"] for row in data["pairs"]]
    assert "platoon_mass_dropout" in names and "rush_hour_deep_fade" in names
    for row in data["pairs"]:
        assert row["rounds_per_hour_stream"] > 0
        assert row["rounds_per_hour_sync"] > 0
        assert len(row["rungs"]) == 4 and sum(row["rungs"]) == row["rounds"]
        assert 0.0 <= row["acc_stream"] <= 1.0

"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family and run one forward + one train step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import api
from repro.models.transformer import forward, loss_fn, unembed
from repro.optim import constant_schedule, make_optimizer


def _batch(cfg, key, B=2, S=24):
    k1, k2, k3 = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
         "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.modality == "vision":
        b["patch_embeds"] = jax.random.normal(k3, (B, cfg.frontend_tokens, 1024))
    if cfg.modality == "audio":
        b["frames"] = jax.random.normal(k3, (B, cfg.encoder_seq, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", sorted(list_archs()))
def test_smoke_forward_and_train(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    B, S = 2, 24
    batch = _batch(cfg, jax.random.PRNGKey(1), B, S)

    hidden, _, aux = forward(params, cfg, batch, logits_mode="hidden")
    assert hidden.shape == (B, S, cfg.d_model)
    logits = unembed(params, cfg, hidden[:, -1:])
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))

    opt = make_optimizer("adamw", constant_schedule(1e-3))
    step = jax.jit(api.make_train_step(cfg, opt))
    state = opt.init(params)
    p1, state, m1 = step(params, state, batch)
    p2, state, m2 = step(p1, state, batch)
    assert bool(jnp.isfinite(m1["loss"])) and bool(jnp.isfinite(m2["loss"]))
    # two steps on the same batch must reduce the loss
    assert float(m2["loss"]) < float(m1["loss"])
    # params actually changed
    changed = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, p2)
    assert max(jax.tree.leaves(changed)) > 0.0

"""repro.exp: spec validation/expansion, cross-process spec determinism,
sweep/single-run bitwise parity on both planner backends, the eval-seed
derivation fix, SweepResult artifacts, Theorem-1 analysis, and the bench
smoke wiring."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep, SweepResult, grid, \
    theorem1_comparison
from repro.fl.rounds import GenFVRunner, RunConfig, eval_stream_seed

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

FAST = dict(rounds=2, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)


# ---------------------------------------------------------------------------
# RunConfig / spec validation (construction-time, with the registry names)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw,fragment", [
    (dict(strategy="sgd"), "unknown strategy"),
    (dict(scenario="autobahn"), "unknown scenario"),
    (dict(planner="torch"), "unknown planner"),
    (dict(dataset="imagenet"), "unknown dataset"),
])
def test_runconfig_rejects_unknown_names(kw, fragment):
    with pytest.raises(ValueError, match=fragment):
        RunConfig(**kw)


def test_runconfig_error_lists_valid_names():
    with pytest.raises(ValueError, match="genfv.*fedavg"):
        RunConfig(strategy="sgd")
    with pytest.raises(ValueError, match="rush_hour.*legacy"):
        RunConfig(scenario="autobahn")
    RunConfig(scenario="legacy")          # the sentinel stays valid


def test_runconfig_frozen():
    run = RunConfig()
    with pytest.raises(Exception):
        run.strategy = "fedavg"


def test_spec_validates_eagerly():
    with pytest.raises(ValueError, match="unknown strategy"):
        ExperimentSpec(strategies=("sgd",))
    with pytest.raises(ValueError, match="unknown scenario"):
        ExperimentSpec(scenarios=("autobahn",))
    with pytest.raises(ValueError, match="unknown planner"):
        ExperimentSpec(overrides=({"planner": "torch"},))
    with pytest.raises(ValueError, match="unknown RunConfig field"):
        ExperimentSpec(overrides=({"lr": 1.0},))
    with pytest.raises(ValueError, match="collides with a grid axis"):
        ExperimentSpec(overrides=({"strategy": "genfv"},))
    with pytest.raises(ValueError, match="axis .* is empty"):
        ExperimentSpec(seeds=())


def test_spec_expand_order_and_cells():
    spec = ExperimentSpec(
        strategies=("genfv", "fedavg"),
        scenarios=("rush_hour", "legacy"),
        seeds=(0, 1),
        base=RunConfig(**FAST),
        overrides=({}, {"planner": "numpy"}),
    )
    cells = spec.expand()
    assert len(cells) == spec.n_cells == 16
    assert [c.index for c in cells] == list(range(16))
    # nested order: strategy slowest, override variant fastest
    assert [c.strategy for c in cells[:8]] == ["genfv"] * 8
    assert cells[0].variant == 0 and cells[1].variant == 1
    assert cells[1].run.planner == "numpy"
    assert cells[0].run.planner == "jax"
    # every cell RunConfig carries its coordinates
    for c in cells:
        assert (c.run.strategy, c.run.scenario, c.run.seed) == \
            (c.strategy, c.scenario, c.seed)
        assert c.run.rounds == FAST["rounds"]


def test_spec_axes_inherit_from_base():
    """An unswept axis takes its single value from the base config — a
    base seed/scenario must never be silently replaced by an axis
    default."""
    base = RunConfig(strategy="fedprox", scenario="platoon", alpha=0.5,
                     seed=7, **{k: v for k, v in FAST.items()})
    spec = ExperimentSpec(base=base)
    (cell,) = spec.expand()
    assert (cell.strategy, cell.scenario, cell.alpha, cell.seed) == \
        ("fedprox", "platoon", 0.5, 7)
    # sweeping one axis keeps the others on the base values
    spec2 = ExperimentSpec(strategies=("genfv", "fedavg"), base=base)
    assert all(c.seed == 7 and c.scenario == "platoon"
               for c in spec2.expand())


def test_spec_json_roundtrip():
    spec = ExperimentSpec(name="rt", strategies=("genfv", "fl_only"),
                          alphas=(0.1, 1.0), base=RunConfig(**FAST),
                          overrides=({"model_bits": 1e6},))
    again = ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    assert again.to_json() == spec.to_json()


def test_spec_to_json_byte_identical_across_processes():
    """Determinism guard (mirrors the rush_hour cross-runner test at the
    process level): two FRESH interpreters serializing the same spec must
    emit identical bytes — no hash-order or repr instability."""
    prog = (
        "from repro.fl.rounds import RunConfig\n"
        "from repro.exp import ExperimentSpec\n"
        "s = ExperimentSpec(name='determinism',"
        " strategies=('genfv','fedavg','fl_only'),"
        " scenarios=('rush_hour','sparse_rural'), alphas=(0.1, 0.3),"
        " seeds=(0, 1, 2), base=RunConfig(rounds=3, train_size=128),"
        " overrides=({}, {'planner': 'numpy', 'model_bits': 32.0}))\n"
        "import sys; sys.stdout.write(s.to_json())\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    outs = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", prog], env=env,
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(r.stdout)
    assert outs[0] == outs[1]
    json.loads(outs[0])                   # and it is valid JSON


def test_grid_cartesian_order():
    cells = grid(a=(1, 2), b=("x", "y", "z"))
    assert cells[:3] == [{"a": 1, "b": "x"}, {"a": 1, "b": "y"},
                         {"a": 1, "b": "z"}]
    assert len(cells) == 6
    assert grid() == [{}]


# ---------------------------------------------------------------------------
# Eval-seed derivation (the seed+999 collision fix)
# ---------------------------------------------------------------------------
def test_eval_seed_no_collision_with_run_seeds():
    """seed+999 gave cell 0's eval set the stream of cell 999's train set;
    the SeedSequence spawn cannot collide with any root integer seed."""
    evals = {eval_stream_seed(s) for s in (0, 1, 999, 1000)}
    assert len(evals) == 4
    assert not evals & {0, 1, 999, 1000}
    # regression shape of the old bug: eval stream of seed s must differ
    # from the train stream of every swept seed
    assert eval_stream_seed(0) != 999


def test_eval_seed_golden():
    """Pins the default-seed eval stream so single-run results don't shift
    again: the derived seed, the label draw, and a pixel checksum of the
    seed=0 eval set. Pixels are process-stable since the procedural
    patterns moved from PYTHONHASHSEED-dependent `hash()` to
    `_stable_seed` (crc32) — which is what makes cross-process checkpoint
    resume (tests/test_faults.py) bitwise in the first place."""
    import numpy as np
    from repro.data.synthetic import make_image_dataset
    assert eval_stream_seed(0) == 8668861027912758289
    imgs, labels = make_image_dataset("cifar10", 512,
                                      seed=eval_stream_seed(0))
    assert labels[:16].tolist() == [5, 8, 8, 4, 8, 5, 2, 5, 9, 4, 3, 5, 7,
                                    3, 0, 7]
    assert imgs.astype(np.float64).sum() == 3020.8941777866858


# ---------------------------------------------------------------------------
# Sweep / single-run parity (the executor's core guarantee)
# ---------------------------------------------------------------------------
PARITY_KEYS = ("loss", "accuracy", "t_bar", "selected", "dropped", "b_gen",
               "kappa2", "emd_bar")


@pytest.mark.parametrize("planner", ["jax", "numpy"])
def test_sweep_matches_single_runs_bitwise(planner):
    """A 2x2 strategy x scenario grid through Sweep.run() must reproduce
    the same cells run one-by-one through GenFVRunner — bitwise, on both
    planner backends (jax batches SUBP2-4 across cells; numpy plans per
    cell on the host)."""
    spec = ExperimentSpec(
        name=f"parity_{planner}",
        strategies=("genfv", "fedavg"),
        scenarios=("rush_hour", "highway_free_flow"),
        base=RunConfig(planner=planner, **FAST),
    )
    result = Sweep(spec, fl_cfg=FAST_CFG).run()
    if planner == "jax":
        # 2 scenarios -> 2 planning groups of 2 fleets, per round
        assert result.meta["planner_dispatches"] == 2 * FAST["rounds"]
        assert result.meta["planner_largest_batch"] == 2
    assert result.meta["dataset_builds"] == 2      # train + eval, shared
    assert result.meta["engines"] == 1
    for cell in spec.expand():
        single = GenFVRunner(cell.run, fl_cfg=FAST_CFG).train()
        for key in PARITY_KEYS:
            np.testing.assert_array_equal(
                result.metrics[key][cell.index], single.curve(key),
                err_msg=f"{cell.strategy}/{cell.scenario}/{key}")


def test_sweep_rerun_identical():
    """Two fresh Sweeps over the same spec produce byte-identical result
    JSON (the dataset's procedural patterns are crc32-seeded, so this holds
    across processes too — the eval-seed golden above pins that)."""
    spec = ExperimentSpec(name="rerun", strategies=("fl_only",),
                          scenarios=("urban_stop_go",),
                          base=RunConfig(**FAST))
    a = Sweep(spec, fl_cfg=FAST_CFG).run()
    b = Sweep(spec, fl_cfg=FAST_CFG).run()
    assert a.to_json() == b.to_json()


# ---------------------------------------------------------------------------
# SweepResult accessors + artifact schema
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_result():
    spec = ExperimentSpec(
        name="small",
        strategies=("genfv", "fl_only"),
        base=RunConfig(**FAST),
    )
    return Sweep(spec, fl_cfg=FAST_CFG).run()


def test_sweep_result_accessors(small_result):
    res = small_result
    acc = res.curve("accuracy", strategy="genfv")
    assert acc.shape == (FAST["rounds"],)
    assert np.all((0.0 <= acc) & (acc <= 1.0))
    with pytest.raises(KeyError, match="matches 2 cells"):
        res.curve("accuracy")
    sub = res.select(strategy="fl_only")
    assert len(sub.cells) == 1
    np.testing.assert_array_equal(sub.metrics["loss"][0],
                                  res.curve("loss", strategy="fl_only"))
    with pytest.raises(KeyError, match="no cells match"):
        res.select(strategy="madca")
    assert res.final("accuracy").shape == (2,)


def test_sweep_artifact_roundtrip(small_result, tmp_path):
    path = small_result.save(directory=str(tmp_path))
    assert path.endswith("small.sweep.json")
    doc = json.load(open(path))
    assert doc["schema"] == "repro.exp/sweep/v1"
    assert doc["spec"]["schema"] == "repro.exp/spec/v1"
    loaded = SweepResult.load(path)
    assert loaded.to_json() == small_result.to_json()
    np.testing.assert_array_equal(loaded.rounds, small_result.rounds)


def test_sweep_select_subset_roundtrips(tmp_path):
    """Regression: a select() subset of a mixed-rounds sweep must save and
    load (max_rounds is the metric column width by contract, and subsets
    trim their columns to the realized width)."""
    spec = ExperimentSpec(
        name="mixed",
        strategies=("fl_only",),
        base=RunConfig(**FAST),
        overrides=({}, {"rounds": 1}),
    )
    res = Sweep(spec, fl_cfg=FAST_CFG).run()
    sub = res.select(variant=1)
    assert sub.metrics["loss"].shape == (1, 1)
    loaded = SweepResult.from_payload(json.loads(sub.to_json()))
    assert loaded.to_json() == sub.to_json()
    np.testing.assert_array_equal(loaded.metrics["loss"],
                                  sub.metrics["loss"])
    # the full result keeps its NaN padding and still round-trips
    full = SweepResult.from_payload(json.loads(res.to_json()))
    assert np.isnan(full.metrics["loss"][1, 1])


def test_sweep_artifact_rejects_wrong_kind(tmp_path):
    p = tmp_path / "bogus.sweep.json"
    p.write_text(json.dumps({"schema": "repro.exp/theorem1/v1"}))
    with pytest.raises(ValueError, match="expected kind"):
        SweepResult.load(str(p))


# ---------------------------------------------------------------------------
# Theorem-1 analysis
# ---------------------------------------------------------------------------
def test_theorem1_comparison(small_result):
    report = theorem1_comparison(small_result)
    assert len(report.rows) == 2
    for row in report.rows:
        assert np.isfinite(row.bound_final) and row.bound_final > 0
        assert row.realized_final > 0
        assert row.tightness > 0
        assert 0.0 <= row.valid_fraction <= 1.0
        assert len(row.bound_curve) == row.rounds == FAST["rounds"]
        assert row.h == FAST_CFG.local_steps
        # the bound contracts (or at worst plateaus) round over round
        assert row.bound_curve[-1] <= row.bound_curve[0] + 1e-9
    scen = report.per_scenario()
    assert [r["scenario"] for r in scen] == ["highway_free_flow"]
    assert scen[0]["cells"] == 2
    md = report.to_markdown()
    assert "highway_free_flow" in md and "tightness" in md


def test_theorem1_artifact(small_result, tmp_path):
    report = theorem1_comparison(small_result)
    path = report.save("t1", directory=str(tmp_path))
    doc = json.load(open(path))
    assert doc["schema"] == "repro.exp/theorem1/v1"
    assert len(doc["rows"]) == 2 and doc["per_scenario"]


# ---------------------------------------------------------------------------
# Bench smoke (tier-1 wiring, mirroring bench_world --quick)
# ---------------------------------------------------------------------------
def test_bench_sweep_quick_smoke(tmp_path):
    out = tmp_path / "BENCH_sweep.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sweep", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["quick"] is True
    assert data["bitwise_parity"] is True
    assert data["n_cells"] == 2
    assert data["meta"]["planner_dispatches"] == 2   # 1 group x 2 rounds
    assert data["meta"]["planner_largest_batch"] == 2

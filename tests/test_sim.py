"""repro.sim: scenario registry, world invariants, AR(1) shadowing,
mid-round dropout, legacy equivalence, and the cross-runner determinism
guard for the persistent vehicular world.
"""
import dataclasses
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.selection import dropout_mask
from repro.fl.rounds import GenFVRunner, RunConfig
from repro.sim import LEGACY, VehicularWorld, get_scenario, scenario_names

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

FAST = dict(rounds=1, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------
def test_registry_presets():
    names = scenario_names()
    assert len(names) >= 5
    for required in ("highway_free_flow", "rush_hour", "urban_stop_go",
                     "platoon", "sparse_rural"):
        assert required in names
    assert LEGACY not in names            # sentinel, not a world scenario
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("autobahn_at_3am")


def test_scenario_apply_overrides():
    cfg = GenFVConfig()
    urban = get_scenario("urban_stop_go").apply(cfg)
    assert urban.rsu_radius == 300.0 and urban.v_max == 50.0
    assert urban.num_vehicles == cfg.num_vehicles      # untouched fields keep
    # a scenario with no geometry override keeps the paper cell
    assert get_scenario("rush_hour").apply(cfg).rsu_radius == cfg.rsu_radius


# ---------------------------------------------------------------------------
# World stepping invariants
# ---------------------------------------------------------------------------
def _world(name="rush_hour", n_partitions=12, seed=0, **cfg_kw):
    scn = get_scenario(name)
    # test kwargs overlay ON TOP of the scenario's own overrides
    cfg = dataclasses.replace(scn.apply(GenFVConfig()), **cfg_kw)
    rng = np.random.default_rng(seed)
    return VehicularWorld(cfg, scn, n_partitions, rng), rng, cfg


def test_world_invariants_over_steps():
    world, rng, cfg = _world()
    half = mobility.coverage_half_length(cfg)
    for _ in range(50):
        world.step(rng, 3.0)
        st = world.state
        assert np.all(np.abs(st.x) <= half + 1e-9)       # nobody out of chord
        assert np.all(np.abs(st.v) >= cfg.v_min - 1e-9)
        assert np.all(np.abs(st.v) <= cfg.v_max + 1e-9)
        bound = st.partition[st.partition >= 0]
        assert len(np.unique(bound)) == len(bound)       # binding is unique
        assert len(np.unique(st.vid)) == st.n            # ids persist uniquely
        assert world.n_bound + len(world._free) == 12    # partition conservation
    assert world.stats.arrivals > 0 and world.stats.departures > 0
    assert world.stats.steps == 50 and world.stats.time == pytest.approx(150.0)


def test_world_population_persists_between_steps():
    """The whole point vs the legacy sampler: most vehicles survive a 3 s
    round and keep their id, position (shifted), and partition binding."""
    world, rng, _ = _world("highway_free_flow", n_partitions=40)
    st0 = world.state
    before = dict(zip(st0.vid.tolist(), st0.partition.tolist()))
    x_before = dict(zip(st0.vid.tolist(), st0.x.tolist()))
    world.step(rng, 3.0)
    st1 = world.state
    common = np.intersect1d(st0.vid, st1.vid)
    assert len(common) >= 0.8 * st0.n                    # most persist
    for vid in common[:10]:
        i = int(np.flatnonzero(st1.vid == vid)[0])
        assert st1.partition[i] == before[vid]           # binding persists
        assert st1.x[i] != x_before[vid]                 # but they moved


def test_world_departures_release_partitions():
    # no arrivals, huge step: everyone crosses out of the chord and the
    # partition pool refills completely
    world, rng, cfg = _world("highway_free_flow", n_partitions=12,
                             arrival_rate=0.0)
    world.step(rng, 1e5)
    assert world.n == 0
    assert sorted(world._free) == list(range(12))
    assert world.stats.departures > 0


def test_world_blocked_arrivals_stay_unbound():
    # 2 partitions, heavy arrivals: the road can exceed the bindable count,
    # extra vehicles ride along unbound (partition = -1)
    world, rng, _ = _world("rush_hour", n_partitions=2)
    for _ in range(20):
        world.step(rng, 3.0)
    assert world.n_bound <= 2
    assert world.n > 2                    # traffic exceeds data-bound fleet
    assert world.stats.blocked_arrivals > 0


def test_shadowing_ar1_memory():
    sigma = 6.0
    # corr_time >> dt: shadowing barely moves within a step
    world, rng, _ = _world("highway_free_flow", n_partitions=4,
                           shadow_sigma_db=sigma, shadow_corr_time=1e6)
    st0 = world.state
    world.step(rng, 1.0)
    st1 = world.state
    common, i0, i1 = np.intersect1d(st0.vid, st1.vid, return_indices=True)
    assert len(common) > 10
    drift = np.abs(st1.shadow_db[i1] - st0.shadow_db[i0])
    assert np.max(drift) < 0.1 * sigma

    # corr_time << dt: memoryless redraw at the stationary std
    world2, rng2, _ = _world("highway_free_flow", n_partitions=4, seed=1,
                             shadow_sigma_db=sigma, shadow_corr_time=1e-6)
    samples = []
    for _ in range(30):
        world2.step(rng2, 1.0)
        samples.append(world2.state.shadow_db.copy())
    flat = np.concatenate(samples)
    assert np.std(flat) == pytest.approx(sigma, rel=0.15)


def test_fleet_view_maps_partitions():
    world, rng, _ = _world("highway_free_flow", n_partitions=6)
    hists = [np.full(10, 0.1) for _ in range(6)]
    hists[2] = np.eye(10)[0]              # partition 2 is single-class
    sizes = [100, 200, 300, 400, 500, 600]
    fleet, parts = world.fleet(hists, sizes)
    assert len(fleet) == world.n_bound
    for v, p in zip(fleet, parts):
        assert v.data_size == sizes[p]
        if p == 2:
            assert v.emd == pytest.approx(1.8)           # 2*(Y-1)/Y
        else:
            assert v.emd == pytest.approx(0.0)
        assert np.isfinite(v.gain_db)


# ---------------------------------------------------------------------------
# Mid-round dropout
# ---------------------------------------------------------------------------
def test_dropout_mask_boundary():
    cfg = GenFVConfig()
    half = mobility.coverage_half_length(cfg)

    def veh(x, v):
        return mobility.Vehicle(0, x, v, 1.0, 1.5e9, 1.3e9, 1.0, 100,
                                np.full(10, .1), 0.0)

    # 36 km/h = 10 m/s: 5 m from the exit edge -> gone in 0.5 s
    fleet = [veh(half - 5.0, 36.0),       # exits mid-round
             veh(-half + 5.0, 36.0),      # just entered, whole chord ahead
             veh(half - 5.0, -36.0),      # near east edge but driving west
             veh(half - 50.0, 36.0)]      # 5 s of headroom
    surv = dropout_mask(cfg, fleet, [0, 1, 2, 3], t_round=3.0)
    np.testing.assert_array_equal(surv, [False, True, True, True])
    assert dropout_mask(cfg, fleet, [], 3.0).shape == (0,)


def test_dropout_threaded_into_roundlog():
    """A runner round where every selected vehicle is about to exit must
    report them all as dropped and train nobody."""
    run = RunConfig(strategy="fedavg", scenario="platoon", seed=0, **FAST)
    r = GenFVRunner(run, fl_cfg=FAST_CFG)
    st = r.world.state
    half = mobility.coverage_half_length(r.cfg)
    # teleport the whole platoon to 1 m before the exit edge at max speed
    st.x[:] = np.sign(st.v) * (half - 1.0)
    log = r.run_round(0)
    assert log.dropped > 0
    assert log.selected == 0              # nobody's update survived
    assert log.dropped + log.selected <= len(st.x) + 1  # sanity


@pytest.mark.parametrize("scenario,seed", [("platoon", 0),
                                           ("sparse_rural", 1)])
def test_partial_dropout_vec_seq_consistent(scenario, seed):
    """Partial mid-round dropout: the fused engine and the sequential
    reference path must agree on who survived, and the participant ledger
    must conserve the planned set (survivor weights renormalize over the
    remaining K' < K — both paths recompute rho over the kept sizes).
    Random selection (fedavg) so near-exit vehicles can be admitted at all:
    SUBP1's holding-time admission would filter the teleported ones out."""
    cfg = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=16)
    logs, planned = [], []
    for vectorized in (True, False):
        run = RunConfig(strategy="fedavg", scenario=scenario, seed=seed,
                        vectorized=vectorized, **FAST)
        r = GenFVRunner(run, fl_cfg=cfg)
        st = r.world.state
        half = mobility.coverage_half_length(r.cfg)
        # teleport every other vehicle to 1 m before its exit edge: part of
        # the fleet (not all of it) drops mid-round
        st.x[::2] = np.sign(st.v[::2]) * (half - 1.0)
        pending = r.begin_round(0)
        plan = r.plan(pending)
        logs.append(r.finish_round(pending, plan))
        planned.append(len(plan.selected))
    a, b = logs
    assert planned[0] == planned[1]
    assert (a.selected, a.dropped) == (b.selected, b.dropped)
    assert a.accuracy == b.accuracy
    assert a.dropped > 0                  # the teleport actually bit
    assert a.selected > 0                 # but survivors carried the round
    # conservation: every planned vehicle either trained or dropped
    # (tiny-partition skips aside, which this fast config does not produce)
    assert a.selected + a.dropped == planned[0]


def test_world_remove_releases_partitions():
    """Forced departures (fault injection) release partition bindings and
    count as departures without consuming any RNG — a benign fault spec
    must leave the world's stream untouched."""
    world, rng, _ = _world("rush_hour", n_partitions=12)
    st = world.state
    victims = st.vid[:2].tolist()
    freed = {int(p) for p in st.partition[:2] if p >= 0}
    state_before = json.loads(json.dumps(rng.bit_generator.state))
    n_before, dep_before = world.n, world.stats.departures
    assert world.remove(victims) == 2
    assert world.n == n_before - 2
    assert world.stats.departures == dep_before + 2
    assert freed <= set(world._free)
    assert not set(victims) & set(world.state.vid.tolist())
    assert rng.bit_generator.state == state_before   # no RNG consumed
    assert world.remove([10 ** 9]) == 0              # unknown vid: no-op


# ---------------------------------------------------------------------------
# Legacy equivalence + determinism guards
# ---------------------------------------------------------------------------
def test_legacy_scenario_reproduces_seed_stats():
    """scenario="legacy" must reproduce the seed's memoryless per-round fleet
    statistics exactly: same RNG draws -> same selection, delays, generation
    schedule, and EMDs. Golden values recorded from this repo at the commit
    introducing repro.sim, running the pre-sim round loop (only the
    fleet/plan statistics are pinned; loss/accuracy golden values would
    have to be re-recorded — the dataset's procedural patterns moved to
    stable crc32 seeding for cross-process checkpoint resume)."""
    run = RunConfig(rounds=2, train_size=300, test_size=32, width_mult=0.0625,
                    strategy="genfv", seed=1, scenario="legacy")
    res = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    golden = [  # (selected, t_bar, b_gen, kappa2, emd_bar)
        (4, 0.19567191773841125, 3, 0.37838433198970145, 1.2302590491269738),
        (4, 0.19158312464063282, 3, 0.37838433198970145, 1.2302590491269738),
    ]
    for log, (sel, t_bar, b_gen, k2, emd_bar) in zip(res.logs, golden):
        assert log.selected == sel
        assert log.t_bar == pytest.approx(t_bar, rel=1e-9)
        assert log.b_gen == b_gen
        assert log.kappa2 == pytest.approx(k2, rel=1e-9)
        assert log.emd_bar == pytest.approx(emd_bar, rel=1e-9)
        assert log.dropped == 0           # legacy has no dropout semantics
        assert np.isfinite(log.loss)


def test_rush_hour_determinism_across_runners():
    """Seeded 3-round rush_hour runs from two FRESH runners must produce
    identical RoundLog curves: world stepping consumes RNG in a fixed order
    and the fused fleet dispatch is deterministic on this backend."""
    curves = []
    for _ in range(2):
        run = RunConfig(rounds=3, train_size=300, test_size=32,
                        width_mult=0.0625, strategy="genfv", seed=0,
                        scenario="rush_hour")
        res = GenFVRunner(run, fl_cfg=FAST_CFG).train()
        curves.append(res)
    for key in ("selected", "dropped", "t_bar", "b_gen", "kappa2", "emd_bar",
                "loss", "accuracy"):
        np.testing.assert_array_equal(curves[0].curve(key),
                                      curves[1].curve(key), err_msg=key)


@pytest.mark.parametrize("scenario", ["highway_free_flow", "rush_hour",
                                      "urban_stop_go", "platoon",
                                      "sparse_rural"])
def test_scenarios_end_to_end(scenario):
    run = RunConfig(strategy="fl_only", scenario=scenario, seed=0, **FAST)
    res = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    assert len(res.logs) == 1
    log = res.logs[0]
    assert np.isfinite(log.loss)
    assert 0.0 <= log.accuracy <= 1.0
    assert log.selected >= 0 and log.dropped >= 0


# ---------------------------------------------------------------------------
# Bench smoke (tier-1 wiring, mirroring bench_rounds --quick)
# ---------------------------------------------------------------------------
def test_bench_world_quick_smoke(tmp_path):
    out = tmp_path / "BENCH_world.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_world", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["quick"] is True
    assert data["throughput"][0]["n_vehicles"] >= 10_000
    assert data["throughput"][0]["vehicle_steps_per_sec"] > 0
    assert data["throughput"][0]["mean_population"] > 5_000
    assert len(data["scenarios"]) >= 1
    row = data["scenarios"][0]
    assert 0.0 <= row["final_accuracy"] <= 1.0

"""Paper-math unit tests: EMD policy (eq. 3-4), Theorem 1, mobility
(eq. 24-27), OFDMA (eq. 9-11), GPU model (eq. 6-8), SUBP1-4 and the joint
two-scale algorithm (Alg. 1-3)."""
import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.core import bandwidth as bw
from repro.core import channel, convergence, emd, generation, gpu_model
from repro.core import mobility, power as pw
from repro.core.selection import select, select_no_emd, select_random
from repro.core.two_scale import plan_round

CFG = GenFVConfig()


# ---------------------------------------------------------------------------
# EMD + weighted policy
# ---------------------------------------------------------------------------
def test_emd_iid_is_zero():
    assert emd.emd(np.full(10, 0.1)) == pytest.approx(0.0)


def test_emd_single_class():
    p = np.zeros(10)
    p[3] = 1.0
    assert emd.emd(p) == pytest.approx(1.8)      # 2*(Y-1)/Y


def test_kappas_match_eq4():
    k1, k2 = emd.kappas(1.0)
    assert k2 == pytest.approx(0.25) and k1 == pytest.approx(0.75)
    k1, k2 = emd.kappas(0.0)
    assert (k1, k2) == (1.0, 0.0)


def test_aggregate_eq4_manual():
    import jax.numpy as jnp
    m1 = {"w": jnp.array([1.0, 2.0])}
    m2 = {"w": jnp.array([3.0, 4.0])}
    aug = {"w": jnp.array([10.0, 10.0])}
    emd_bar = 1.0                                 # k2 = 0.25
    out = emd.aggregate([m1, m2], [0.5, 0.5], aug, emd_bar)
    expect = 0.75 * np.array([2.0, 3.0]) + 0.25 * np.array([10.0, 10.0])
    np.testing.assert_allclose(np.asarray(out["w"]), expect, rtol=1e-6)


# ---------------------------------------------------------------------------
# Theorem 1
# ---------------------------------------------------------------------------
def test_convergence_bound_contracts():
    p = convergence.ConvergenceParams()
    assert convergence.chi(p) < 1.0
    rhos, lams = [0.5, 0.5], [0.1, 0.2]
    b = convergence.bound_curve(p, 50, rhos, lams, 0.8, 0.2)
    assert b[0] == pytest.approx(p.theta)
    assert np.all(np.diff(b) <= 1e-9)            # monotone toward the floor
    floor = convergence.psi(p) * convergence.big_lambda(p, rhos, lams, 0.8, 0.2)
    asymptote = convergence.bound(p, 3000, rhos, lams, 0.8, 0.2)
    assert asymptote == pytest.approx(floor, rel=1e-2)
    assert b[-1] >= floor - 1e-9


def test_convergence_worse_data_bigger_bound():
    p = convergence.ConvergenceParams()
    good = convergence.bound(p, 30, [1.0], [0.05], 0.9, 0.1)
    bad = convergence.bound(p, 30, [1.0], [0.50], 0.9, 0.1)
    assert bad > good


def test_convergence_requires_small_eta():
    p = convergence.ConvergenceParams(eta=0.2, varrho=10.0)
    with pytest.raises(AssertionError):
        convergence.bound(p, 10, [1.0], [0.1], 0.9, 0.1)


# ---------------------------------------------------------------------------
# Mobility (eq. 24-27)
# ---------------------------------------------------------------------------
def test_average_speed_congestion():
    free = mobility.average_speed(CFG, 0)
    jam = mobility.average_speed(CFG, CFG.m_max)
    assert free == CFG.v_max and jam == CFG.v_min


def test_holding_time_geometry():
    half = mobility.coverage_half_length(CFG)
    # vehicle at the entry edge moving forward crosses the whole chord
    t_full = mobility.holding_time(CFG, -half, 60.0)
    t_half = mobility.holding_time(CFG, 0.0, 60.0)
    assert t_full == pytest.approx(2 * t_half, rel=1e-6)
    # about to leave -> ~0
    assert mobility.holding_time(CFG, half, 60.0) == pytest.approx(0.0)


def test_remaining_distance_sign_convention():
    """Eq. (25): the remaining distance is measured in the direction of
    travel — mirrored positions/directions must agree, and driving away
    from the near edge leaves the whole remaining chord."""
    half = mobility.coverage_half_length(CFG)
    # eastbound at +100 m has 'half - 100' left; westbound at -100 m mirrors
    assert mobility.remaining_distance(CFG, 100.0, 60.0) == \
        pytest.approx(half - 100.0)
    assert mobility.remaining_distance(CFG, -100.0, -60.0) == \
        pytest.approx(half - 100.0)
    # driving back toward the far edge: remaining distance grows past half
    assert mobility.remaining_distance(CFG, 100.0, -60.0) == \
        pytest.approx(half + 100.0)
    assert mobility.remaining_distance(CFG, -100.0, 60.0) == \
        pytest.approx(half + 100.0)
    # vectorized variant agrees with the scalar one
    xs = np.array([100.0, -100.0, 100.0, -100.0])
    vs = np.array([60.0, -60.0, -60.0, 60.0])
    np.testing.assert_allclose(
        mobility.remaining_distances(CFG, xs, vs),
        [mobility.remaining_distance(CFG, x, v) for x, v in zip(xs, vs)])


def test_holding_time_edge_cases():
    half = mobility.coverage_half_length(CFG)
    # |v| at the v_min floor: slowest legal crossing, finite and maximal
    t_slow = mobility.holding_time(CFG, -half, CFG.v_min)
    t_fast = mobility.holding_time(CFG, -half, CFG.v_max)
    assert np.isfinite(t_slow) and t_slow > t_fast
    assert t_slow == pytest.approx(2 * half / (CFG.v_min / 3.6), rel=1e-6)
    # at and beyond the exit boundary the holding time clamps to zero
    assert mobility.holding_time(CFG, half, 60.0) == 0.0
    assert mobility.holding_time(CFG, half + 50.0, 60.0) == 0.0
    assert mobility.holding_time(CFG, -half - 50.0, -60.0) == 0.0
    # vectorized variant matches and clamps the same way
    xs = np.array([-half, half, half + 50.0])
    vs = np.array([CFG.v_min, 60.0, 60.0])
    np.testing.assert_allclose(
        mobility.holding_times(CFG, xs, vs),
        [mobility.holding_time(CFG, x, v) for x, v in zip(xs, vs)])


def test_sample_fleet_road_load_uses_uncapped_draw(rng):
    """Eq. 24 congestion must see every vehicle the Poisson process put on
    the road, not just the ones that fit the available data partitions —
    with a huge arrival mean and few partitions the road is jammed and
    speeds sit at the v_min floor (the pre-fix code passed the capped count
    and sampled free-flow speeds instead)."""
    import dataclasses
    cfg = dataclasses.replace(CFG, num_vehicles=500)    # m_max = 60: jam
    hists = rng.dirichlet(np.full(10, 0.3), size=5)
    sizes = rng.integers(500, 2000, size=5)
    fleet = mobility.sample_fleet(rng, cfg, hists, sizes)
    assert len(fleet) == 5                              # capped to partitions
    speeds = np.abs([v.v for v in fleet])
    # v_bar = v_min = 10 km/h; the buggy capped count gave v_bar ~ 110 km/h
    assert np.mean(speeds) < 30.0


# ---------------------------------------------------------------------------
# Channel + GPU models
# ---------------------------------------------------------------------------
def test_uplink_rate_monotonic():
    r1 = channel.uplink_rate(CFG, 1.0, 0.5, 200.0)
    r2 = channel.uplink_rate(CFG, 1.0, 1.0, 200.0)    # more power
    r3 = channel.uplink_rate(CFG, 2.0, 0.5, 200.0)    # more bandwidth
    r4 = channel.uplink_rate(CFG, 1.0, 0.5, 400.0)    # farther
    assert r2 > r1 and r3 > r1 and r4 < r1
    assert r3 == pytest.approx(2 * r1)               # rate linear in l_n


def test_snr_monotone_in_distance_and_shadowing():
    dists = np.linspace(50.0, 1000.0, 40)
    snrs = np.array([channel.snr(CFG, 0.5, d) for d in dists])
    assert np.all(np.diff(snrs) < 0)                 # strictly decreasing
    # shadowing: +3 dB gain doubles SNR (10^(3/10) ~ 2), -3 dB halves it
    base = channel.snr(CFG, 0.5, 200.0)
    assert channel.snr(CFG, 0.5, 200.0, gain_db=3.0) == \
        pytest.approx(base * 10 ** 0.3)
    assert channel.snr(CFG, 0.5, 200.0, gain_db=-3.0) == \
        pytest.approx(base / 10 ** 0.3)
    # 0 dB reproduces the unshadowed value bitwise (legacy equivalence)
    assert channel.snr(CFG, 0.5, 200.0, gain_db=0.0) == base
    # faded uplink takes longer
    assert channel.upload_time(CFG, 1e6, 1.0, 0.5, 200.0, gain_db=-10.0) > \
        channel.upload_time(CFG, 1e6, 1.0, 0.5, 200.0)


def test_gpu_energy_eq8():
    v = mobility.Vehicle(0, 0.0, 50.0, 1.0, 1.5e9, 1.3e9, 1.0, 1000,
                         np.full(10, .1), 0.0)
    t = gpu_model.train_time(v, 8)
    p = gpu_model.runtime_power(v)
    assert gpu_model.train_energy(v, 8) == pytest.approx(p * t)
    assert gpu_model.train_time(v, 16) > t           # more batches -> slower


# ---------------------------------------------------------------------------
# SUBP2 bandwidth (Alg. 1)
# ---------------------------------------------------------------------------
def test_bandwidth_respects_budget_and_helps_stragglers():
    A = np.array([0.5, 0.5, 0.5])
    B = np.array([1.0, 2.0, 4.0])        # third vehicle has worst channel
    C = np.zeros(3)
    D = 0.5 * B
    res = bw.solve_bandwidth(A, B, C, D, M=6.0, e_bar=10.0)
    assert res.l.sum() <= 6.0 + 1e-6
    assert res.l[2] > res.l[1] > res.l[0]            # worse channel -> more l
    # min-max delay below the equal-share baseline
    eq = float(np.max(A + B / bw.equal_share(3, 6.0)))
    assert res.t_bar <= eq + 1e-6


def test_project_budget_iterates_when_floor_binds():
    """Regression: a single rescale + floor can overshoot the budget. With
    l = [10, 0.06, 0.06], M = 5: one rescale gives [4.94, ~0.03, ~0.03],
    flooring the small entries to 0.05 pushes the sum to 5.04 > M. The
    iterated projection pins them and refills the free entry instead."""
    l = bw.project_budget(np.array([10.0, 0.06, 0.06]), M=5.0, l_min=0.05)
    assert l.sum() <= 5.0 + 1e-9
    np.testing.assert_allclose(l, [4.9, 0.05, 0.05])
    # no-bind case: plain rescale, already-feasible input untouched
    np.testing.assert_allclose(
        bw.project_budget(np.array([4.0, 4.0]), 4.0, 0.05), [2.0, 2.0])
    easy = np.array([1.0, 2.0])
    np.testing.assert_array_equal(bw.project_budget(easy, 4.0, 0.05), easy)
    # infeasible budget: every entry pins at the floor (documented)
    np.testing.assert_allclose(
        bw.project_budget(np.array([1.0, 1.0, 1.0]), 0.1, 0.05), 0.05)


def test_bandwidth_budget_property_randomized(rng):
    """Property: across random A/B/C/D instances the returned allocation
    always satisfies sum(l) <= M and l >= l_min (the pre-fix projection
    violated the budget whenever the floor bound after rescaling)."""
    for _ in range(40):
        n = int(rng.integers(1, 24))
        A = rng.uniform(0.0, 1.0, n)
        B = 10.0 ** rng.uniform(-3, 2, n)          # wildly mixed channels
        C = rng.uniform(0.0, 2.0, n)
        D = rng.uniform(0.0, 2.0, n) * B
        l_min = 0.05
        M = float(rng.uniform(n * l_min * 1.01, 20.0))
        res = bw.solve_bandwidth(A, B, C, D, M=M,
                                 e_bar=float(rng.uniform(0.5, 20.0)),
                                 l_min=l_min)
        assert res.l.sum() <= M + 1e-9, (n, M, res.l.sum())
        assert np.all(res.l >= l_min - 1e-12)


# ---------------------------------------------------------------------------
# SUBP3 power (Alg. 2)
# ---------------------------------------------------------------------------
def test_power_sca_hits_max_when_energy_slack():
    l_w = np.full(3, 2e7)
    b_prime = np.full(3, 1e4)
    G = np.zeros(3)
    res = pw.solve_power(1e8, l_w, b_prime, G, e_bar=100.0, phi_min=0.1,
                         phi_max=1.0)
    np.testing.assert_allclose(res.phi, 1.0, atol=1e-3)   # delay-optimal
    assert res.converged


def test_power_sca_respects_energy():
    l_w = np.full(2, 1e7)
    b_prime = np.full(2, 1e3)
    G = np.array([0.0, 0.0])
    e_bar = 2.0
    res = pw.solve_power(3e8, l_w, b_prime, G, e_bar, 0.05, 1.0)
    e = pw.e_of_phi(3e8, l_w, b_prime, res.phi) + G
    assert np.all(e <= e_bar * 1.05)
    # delay decreases with power within the feasible set
    t = pw.t_of_phi(3e8, l_w, b_prime, res.phi)
    t_min = pw.t_of_phi(3e8, l_w, b_prime, np.full(2, 0.05))
    assert np.all(t <= t_min)


def test_power_converged_flag_exact_on_last_iteration():
    """Regression: a solve hitting the eps fixed point exactly on iteration
    max_iter used to report converged=False (the flag was `it < max_iter`).
    Re-running with max_iter pinned to the iteration that converged must
    still report success."""
    l_w = np.full(3, 2e7)
    b_prime = np.full(3, 1e4)
    G = np.zeros(3)
    free = pw.solve_power(1e8, l_w, b_prime, G, e_bar=100.0, phi_min=0.1,
                          phi_max=1.0)
    assert free.converged and free.iters >= 2
    pinned = pw.solve_power(1e8, l_w, b_prime, G, e_bar=100.0, phi_min=0.1,
                            phi_max=1.0, max_iter=free.iters)
    assert pinned.converged
    np.testing.assert_array_equal(pinned.phi, free.phi)
    # a cap genuinely too small still reports non-convergence
    assert not pw.solve_power(1e8, l_w, b_prime, G, 100.0, 0.1, 1.0,
                              max_iter=1).converged


# ---------------------------------------------------------------------------
# SUBP4 generation (eq. 48)
# ---------------------------------------------------------------------------
def test_generation_closed_form():
    svc = generation.DiffusionService()
    b = generation.optimal_generation(t_bar=2.0, b_prev=0, svc=svc)
    assert b == int(np.floor((2.0 - gpu_model.rsu_train_time(1)) / svc.t_per_image))
    assert generation.optimal_generation(0.001, 0, svc) == 0


def test_label_schedule_uniform():
    counts = generation.label_schedule(103, 10)
    assert counts.sum() == 103
    assert counts.max() - counts.min() <= 1


# ---------------------------------------------------------------------------
# SUBP1 + Algorithm 3
# ---------------------------------------------------------------------------
def _fleet(rng, n=30, alpha=0.3):
    hists = rng.dirichlet(np.full(10, alpha), size=n)
    sizes = rng.integers(500, 2000, size=n)
    return mobility.sample_fleet(rng, CFG, hists, sizes)


def test_selection_emd_threshold(rng):
    fleet = _fleet(rng)
    res = select(CFG, fleet, model_bits=1e6, batches=4, emd_hat=0.8)
    for v, a in zip(fleet, res.alpha):
        if v.emd > 0.8:
            assert a == 0
    # dropout-accounting stats: raw eq.-26 holding time, t_bar caps at t_max
    np.testing.assert_allclose(
        res.t_hold, [mobility.holding_time(CFG, v.x, v.v) for v in fleet])
    np.testing.assert_allclose(res.t_bar, np.minimum(res.t_hold, CFG.t_max))
    loose = select(CFG, fleet, model_bits=1e6, batches=4, emd_hat=10.0)
    assert loose.alpha.sum() >= res.alpha.sum()


def test_selection_reasons_lazy_and_consistent(rng):
    """reasons are formatted on first access only, and agree with alpha."""
    fleet = _fleet(rng, n=12)
    res = select(CFG, fleet, model_bits=352e6, batches=8, emd_hat=0.9)
    assert res._reasons is None                    # nothing formatted yet
    reasons = res.reasons
    assert res._reasons is reasons                 # cached after first use
    assert len(reasons) == len(fleet)
    for v, a, r in zip(fleet, res.alpha, reasons):
        assert r.startswith(f"v{v.vid}: ")
        assert ("selected" in r) == bool(a)
        if v.emd > 0.9:
            assert "EMD" in r


def test_no_emd_superset(rng):
    fleet = _fleet(rng)
    strict = select(CFG, fleet, 352e6, 8).alpha
    loose = select_no_emd(CFG, fleet, 352e6, 8)
    assert np.all(loose >= strict)


def test_two_scale_plan(rng):
    fleet = _fleet(rng)
    plan = plan_round(CFG, fleet, model_bits=352e6, batches=8)
    if plan.selected:
        K = len(plan.selected)
        assert plan.l.shape == (K,) and plan.phi.shape == (K,)
        assert plan.l.sum() <= CFG.num_subcarriers + 1e-6
        assert np.all(plan.phi >= CFG.phi_min - 1e-9)
        assert np.all(plan.phi <= np.array(
            [fleet[i].phi_max for i in plan.selected]) + 1e-9)
        assert plan.t_bar == pytest.approx(float(np.max(plan.t_cp + plan.t_mu)))
        assert plan.b_gen >= 0
        # BCD objective is non-increasing overall
        assert plan.history[-1] <= plan.history[0] + 1e-6
        # RSU finishes inside the straggler window (eq. 21 with t_max cap)
        assert plan.t_rsu <= min(plan.t_bar, CFG.t_max) + 0.5


def test_two_scale_beats_naive(rng):
    """Allocated (l*, phi*) must not be worse than equal-share at phi_min."""
    fleet = _fleet(rng)
    plan = plan_round(CFG, fleet, model_bits=352e6, batches=8)
    if not plan.selected:
        pytest.skip("no vehicles selected in this draw")
    sub = [fleet[i] for i in plan.selected]
    n0 = channel.noise_watts(CFG)
    dists = np.array([mobility.rsu_distance(CFG, v.x) for v in sub])
    b_prime = CFG.unit_channel_gain * dists ** (-CFG.path_loss_exp) / n0
    l_eq = bw.equal_share(len(sub), CFG.num_subcarriers)
    t_naive = pw.t_of_phi(352e6, l_eq * CFG.subcarrier_bw, b_prime,
                          np.full(len(sub), CFG.phi_min))
    naive = float(np.max(plan.t_cp + t_naive))
    assert plan.t_bar <= naive + 1e-6

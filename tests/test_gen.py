"""repro.gen — the AIGC dataplane (batched sampler, round-keyed service,
calibration, pretrain checkpoint, sweep axis, runner integration)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

import repro.gen.service as gen_service
from repro.configs.base import GenFVConfig
from repro.core.generation import label_schedule
from repro.diffusion.ddpm import DDPM, make_ddpm
from repro.exp.spec import ExperimentSpec
from repro.fl.generator import ORACLE_CACHE_SIZE, OracleGenerator, \
    _oracle_pattern
from repro.fl.rounds import GenFVRunner, RunConfig
from repro.gen.calib import (CALIB_BUCKET, MeasuredService, _calib_key,
                             calibrated_service, load_calibration,
                             save_calibration)
from repro.gen.pretrain import load_pretrained, pretrain_ddpm
from repro.gen.sampler import sample_schedule, strided_timesteps
from repro.gen.service import (BatchedDDPMGenerator, gen_round_key,
                               make_ddpm_generator)

TINY = DDPM(timesteps=8, num_classes=4, base_width=8)

#: shrunk "foundation model" budget for the runner-integration tests: the
#: deterministic pretrain contract doesn't care about scale, and the
#: service's lru key includes the full budget so these never alias the
#: real defaults.
TINY_BUDGET = dict(RUNNER_TIMESTEPS=8, RUNNER_BASE_WIDTH=8,
                   PRETRAIN_STEPS=2, PRETRAIN_REF=64)

FAST = dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)


@pytest.fixture(scope="module")
def tiny_params():
    return make_ddpm(jax.random.PRNGKey(0), TINY)


def _use_tiny_service(monkeypatch, tmp_path):
    """Shrink the ddpm dataplane for runner tests: tiny model + pretrain
    budget, calibration redirected to tmp and PRE-SEEDED with the paper's
    assumed t0 — so eq. 48's b* stays at oracle scale and no wall-clock
    measurement (nondeterministic across runs) enters the test."""
    for k, v in TINY_BUDGET.items():
        monkeypatch.setattr(gen_service, k, v)
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path / "artifacts"))
    ddpm = DDPM(timesteps=TINY_BUDGET["RUNNER_TIMESTEPS"], num_classes=10,
                base_width=TINY_BUDGET["RUNNER_BASE_WIDTH"])
    key = _calib_key(ddpm, 2, CALIB_BUCKET)
    save_calibration({key: {"t_image": 0.05, "bucket": CALIB_BUCKET,
                            "sampler_steps": 2}})
    return ddpm


def _ddpm_run(**over):
    kw = dict(strategy="genfv", seed=0, generator="ddpm", sampler_steps=2,
              **FAST)
    kw.update(over)
    return RunConfig(**kw)


# ---------------------------------------------------------------------------
# strided schedule
# ---------------------------------------------------------------------------
def test_strided_timesteps_endpoints():
    ts = strided_timesteps(200, 5)
    assert ts[0] == 0 and ts[-1] == 199
    assert list(ts) == sorted(set(ts))
    assert np.array_equal(strided_timesteps(200, 200), np.arange(200))
    assert list(strided_timesteps(8, 1)) == [7]


def test_strided_timesteps_rejects_bad_counts():
    with pytest.raises(ValueError):
        strided_timesteps(200, 0)
    with pytest.raises(ValueError):
        strided_timesteps(200, 201)


# ---------------------------------------------------------------------------
# batched sampler: bitwise parity + schedule conservation
# ---------------------------------------------------------------------------
def test_batched_matches_per_label_loop_bitwise(tiny_params):
    """One fused dispatch over a multi-label schedule == the per-label
    reference loop, bit for bit, because every image's noise is keyed by
    its global schedule index (not its batch position)."""
    key = gen_round_key(seed=5, round_idx=2)
    counts = np.array([2, 0, 3, 1])          # includes an empty label
    labels = np.repeat(np.arange(4), counts).astype(np.int32)

    fused = sample_schedule(tiny_params, TINY, key, labels, 4)

    parts, off = [], 0
    for lab, c in enumerate(counts):
        if c == 0:
            continue
        parts.append(sample_schedule(tiny_params, TINY, key,
                                     [lab] * int(c), 4, start=off))
        off += int(c)
    assert np.array_equal(fused, np.concatenate(parts))


def test_bucket_padding_is_bitwise_neutral(tiny_params):
    key = gen_round_key(seed=1, round_idx=0)
    labels = [0, 1, 2, 3, 0, 1]
    a = sample_schedule(tiny_params, TINY, key, labels, 4, bucket=8)
    b = sample_schedule(tiny_params, TINY, key, labels, 4, bucket=32)
    assert np.array_equal(a, b)


def test_generator_schedule_conservation(tiny_params):
    """Eq.-48 conservation: the generator returns exactly the b* images of
    the label schedule, per label — including b=0, b < num_classes (extras
    land on the first classes) and the single-label edge."""
    gen = BatchedDDPMGenerator(tiny_params, TINY, seed=0, sampler_steps=2)
    rng = np.random.default_rng(0)
    for b in (0, 1, 3, 11):
        counts = label_schedule(b, TINY.num_classes)
        assert counts.sum() == b
        labels = np.repeat(np.arange(TINY.num_classes), counts)
        imgs = gen.generate(labels, rng, round_idx=0)
        assert imgs.shape == (b, 32, 32, 3)
        got = np.bincount(labels[: len(imgs)], minlength=TINY.num_classes)
        assert np.array_equal(got, counts)
    # single-label schedule
    imgs = gen.generate(np.full(5, 2, np.int32), rng, round_idx=1)
    assert imgs.shape == (5, 32, 32, 3)


def test_generate_is_round_keyed_and_rng_silent(tiny_params):
    """Same (seed, round) -> bitwise-identical images regardless of the
    shared numpy stream's state; different rounds diverge; the shared
    stream is never consumed (the checkpoint-resume contract)."""
    gen = BatchedDDPMGenerator(tiny_params, TINY, seed=3, sampler_steps=2)
    labels = np.array([0, 1, 1, 2])

    rng = np.random.default_rng(0)
    state_before = rng.bit_generator.state
    a = gen.generate(labels, rng, round_idx=7)
    assert rng.bit_generator.state == state_before

    rng.normal(size=100)                     # perturb the shared stream
    b = gen.generate(labels, rng, round_idx=7)
    assert np.array_equal(a, b)

    c = gen.generate(labels, rng, round_idx=8)
    assert not np.array_equal(a, c)


def test_gen_round_key_distinct_per_seed_and_round():
    keys = {tuple(np.asarray(gen_round_key(s, t)))
            for s in range(3) for t in range(3)}
    assert len(keys) == 9


# ---------------------------------------------------------------------------
# oracle satellite: bounded pattern cache, round_idx pass-through
# ---------------------------------------------------------------------------
def test_oracle_pattern_cache_bounded():
    info = _oracle_pattern.cache_info()
    assert info.maxsize == ORACLE_CACHE_SIZE is not None
    for f in np.linspace(0.0, 1.0, ORACLE_CACHE_SIZE + 40):
        _oracle_pattern("cifar10", 0, float(f))
    assert _oracle_pattern.cache_info().currsize <= ORACLE_CACHE_SIZE


def test_oracle_round_kwarg_is_bitwise_neutral():
    gen = OracleGenerator("cifar10")
    labels = np.array([0, 1, 2])
    a = gen.generate(labels, np.random.default_rng(9))
    b = gen.generate(labels, np.random.default_rng(9), round_idx=5)
    assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# calibration artifact
# ---------------------------------------------------------------------------
def test_calibration_roundtrip_and_cache_hit(tiny_params, monkeypatch,
                                             tmp_path):
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path))
    svc = calibrated_service(tiny_params, TINY, sampler_steps=2, bucket=4)
    assert svc.t_per_image > 0 and svc.steps == 2
    entries = load_calibration()
    assert len(entries) == 1

    # second lookup must hit the artifact, not the sampler
    import repro.gen.calib as calib
    monkeypatch.setattr(calib, "measure_t_per_image",
                        lambda *a, **k: pytest.fail("re-measured on hit"))
    again = calibrated_service(tiny_params, TINY, sampler_steps=2, bucket=4)
    assert again == svc


def test_calibration_ignores_foreign_file(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path))
    path = tmp_path / "gen_calib.json"
    path.write_text('{"schema": "something/else", "entries": {"x": {}}}')
    assert load_calibration() == {}


# ---------------------------------------------------------------------------
# pretrain: determinism + checkpoint
# ---------------------------------------------------------------------------
def test_pretrain_deterministic_and_checkpointed(tmp_path):
    ddpm = DDPM(timesteps=8, num_classes=10, base_width=8)
    ck = str(tmp_path / "ddpm")
    p1, losses = pretrain_ddpm(ddpm, steps=2, ref_size=32, ckpt_path=ck)
    assert len(losses) == 2
    # a second call restores from the checkpoint (no training: empty losses)
    p2, losses2 = pretrain_ddpm(ddpm, steps=2, ref_size=32, ckpt_path=ck)
    assert losses2 == []
    # and a from-scratch rerun reconstructs the same params bitwise
    p3, _ = pretrain_ddpm(ddpm, steps=2, ref_size=32)
    for a, b, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p2),
                       jax.tree.leaves(p3)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(a), np.asarray(c))

    restored = load_pretrained(ck, ddpm)
    assert len(jax.tree.leaves(restored)) == len(jax.tree.leaves(p1))
    with pytest.raises(ValueError):
        load_pretrained(ck, DDPM(timesteps=16, num_classes=10, base_width=8))


def test_pretrain_rejects_class_mismatch():
    with pytest.raises(ValueError):
        pretrain_ddpm(DDPM(num_classes=7), steps=1, ref_size=8)


# ---------------------------------------------------------------------------
# ExperimentSpec sampler_steps axis
# ---------------------------------------------------------------------------
def test_spec_sampler_steps_axis():
    spec = ExperimentSpec(name="steps", sampler_steps=(2, 8),
                          base=RunConfig(**FAST))
    assert spec.n_cells == 2
    cells = spec.expand()
    assert [c.run.sampler_steps for c in cells] == [2, 8]
    assert [c.sampler_steps for c in cells] == [2, 8]
    assert cells[0].coords()["sampler_steps"] == 2

    again = ExperimentSpec.from_json(spec.to_json())
    assert again.to_json() == spec.to_json()


def test_spec_sampler_steps_inherits_and_loads_old_payloads():
    spec = ExperimentSpec(base=RunConfig(sampler_steps=25, **FAST))
    assert spec.sampler_steps == (25,)
    payload = spec.to_payload()
    del payload["axes"]["sampler_steps"]     # pre-axis artifact
    old = ExperimentSpec.from_payload(payload)
    assert old.sampler_steps == (25,)


def test_run_config_validates_generator_fields():
    with pytest.raises(ValueError):
        RunConfig(generator="gan")
    with pytest.raises(ValueError):
        RunConfig(sampler_steps=0)


# ---------------------------------------------------------------------------
# runner integration: end-to-end ddpm rounds, one dispatch per round,
# measured svc in the planner, bitwise golden resume
# ---------------------------------------------------------------------------
def test_ddpm_runner_end_to_end_one_dispatch_per_round(monkeypatch,
                                                       tmp_path):
    _use_tiny_service(monkeypatch, tmp_path)
    calls = []
    real = gen_service.sample_schedule
    monkeypatch.setattr(gen_service, "sample_schedule",
                        lambda *a, **k: (calls.append(1), real(*a, **k))[1])

    runner = GenFVRunner(_ddpm_run(), fl_cfg=FAST_CFG)
    assert isinstance(runner.server.generator, BatchedDDPMGenerator)
    assert isinstance(runner.svc, MeasuredService)
    assert runner.svc.t_per_image == 0.05    # the pre-seeded calibration
    res = runner.train()

    assert len(res.logs) == FAST["rounds"]
    gen_rounds = sum(1 for l in res.logs if l.b_gen > 0)
    assert gen_rounds > 0
    # exactly ONE batched sampling dispatch per generating round
    assert len(calls) == gen_rounds
    assert all(np.isfinite(l.accuracy) for l in res.logs)


def test_ddpm_runner_golden_resume_bitwise(monkeypatch, tmp_path):
    """Kill after round 1, resume from the checkpoint in a fresh runner:
    the remaining rounds replay bitwise, with the planner pricing eq. 48
    against the RECORDED t0 (a poisoned calibration file on the resume
    host must not perturb the replanned rounds)."""
    ddpm = _use_tiny_service(monkeypatch, tmp_path)
    run = _ddpm_run()
    ck = str(tmp_path / "runner.npz")

    golden_runner = GenFVRunner(run, fl_cfg=FAST_CFG)
    golden = golden_runner.train()

    first = GenFVRunner(run, fl_cfg=FAST_CFG)
    first.run_round(0)
    first.save_checkpoint(ck)

    # resume on a "different host": calibration now claims another t0
    key = _calib_key(ddpm, run.sampler_steps, CALIB_BUCKET)
    save_calibration({key: {"t_image": 0.9, "bucket": CALIB_BUCKET,
                            "sampler_steps": run.sampler_steps}})
    resumed = GenFVRunner(run, fl_cfg=FAST_CFG)
    assert resumed.svc.t_per_image == 0.9
    resumed.load_checkpoint(ck)
    assert resumed.svc.t_per_image == 0.05   # checkpoint overrode it
    res = resumed.train()

    assert [vars(a) for a in res.logs] == [vars(g) for g in golden.logs]
    for a, g in zip(jax.tree.leaves(resumed.server.params),
                    jax.tree.leaves(golden_runner.server.params)):
        assert np.array_equal(np.asarray(a), np.asarray(g))


def test_ddpm_generator_factory_is_deterministic(monkeypatch, tmp_path):
    _use_tiny_service(monkeypatch, tmp_path)
    g1 = make_ddpm_generator("cifar10", 10, seed=0, sampler_steps=2)
    g2 = make_ddpm_generator("cifar10", 10, seed=0, sampler_steps=2)
    assert g1.params is g2.params            # in-process lru share
    labels = np.array([0, 5, 9])
    rng = np.random.default_rng(0)
    assert np.array_equal(g1.generate(labels, rng, round_idx=2),
                          g2.generate(labels, rng, round_idx=2))


def test_oracle_runner_has_no_measured_service():
    runner = GenFVRunner(RunConfig(**FAST), fl_cfg=FAST_CFG)
    assert runner.svc is None
    assert isinstance(runner.server.generator, OracleGenerator)


# ---------------------------------------------------------------------------
# bench smoke (tier-1 CI surface of benchmarks/bench_gen.py)
# ---------------------------------------------------------------------------
def test_bench_gen_quick_smoke(tmp_path):
    import json
    out = tmp_path / "BENCH_gen.json"
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_ARTIFACTS=str(tmp_path / "artifacts"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_gen", "--quick",
         "--out", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    doc = json.loads(out.read_text())
    assert doc["quick"] is True
    assert doc["results"]["throughput"]
    assert doc["results"]["batched_vs_sequential"]["speedup"] > 1.0

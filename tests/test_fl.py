"""FL runtime: GenFV rounds end-to-end (reduced scale), server aggregation,
generators, and the data pipeline."""
import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.data.synthetic import make_image_dataset, make_token_dataset, batch_tokens
from repro.fl.generator import OracleGenerator
from repro.fl.rounds import GenFVRunner, RunConfig, STRATEGIES

FAST = dict(rounds=2, train_size=600, test_size=64, width_mult=0.125)
FAST_CFG = GenFVConfig(batch_size=16, local_steps=2, num_vehicles=8)


def test_dataset_determinism():
    a1, l1 = make_image_dataset("cifar10", 32, seed=5)
    a2, l2 = make_image_dataset("cifar10", 32, seed=5)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(l1, l2)
    assert a1.shape == (32, 32, 32, 3)
    assert a1.min() >= -1.0 and a1.max() <= 1.0


def test_dataset_class_structure():
    """Same-class samples must be closer than CROSS-PAIR samples (classes
    2c and 2c+1 intentionally share their coarse shape — the AIGC quality
    ceiling design, data/synthetic.py)."""
    imgs, labels = make_image_dataset("cifar10", 400, seed=0, noise=0.1)
    intra, inter_pair, inter_far = [], [], []
    for c in range(0, 6, 2):
        a = imgs[labels == c]
        b = imgs[labels == c + 1]           # same coarse pair
        f = imgs[labels == (c + 2) % 10]    # different pair
        if len(a) > 1 and len(b) > 0 and len(f) > 0:
            intra.append(np.mean((a[0] - a[1]) ** 2))
            inter_pair.append(np.mean((a[0] - b[0]) ** 2))
            inter_far.append(np.mean((a[0] - f[0]) ** 2))
    assert np.mean(intra) < np.mean(inter_far)
    # paired classes are closer than cross-pair (the designed structure)
    assert np.mean(inter_pair) < np.mean(inter_far)


def test_token_stream():
    toks = make_token_dataset(100, 5000, seed=0)
    assert toks.min() >= 0 and toks.max() < 100
    b = batch_tokens(toks, batch=4, seq=16, step=3)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_oracle_generator_vectorized_matches_loop():
    """The batched pattern-lookup + gather-roll implementation must be
    bitwise-identical to the seed's per-image loop (same rng protocol)."""
    from repro.data.synthetic import _coarse_pattern, _fine_pattern
    gen = OracleGenerator("cifar10", fine_frac=0.4, noise=0.3)
    labels = np.array([0, 3, 3, 9, 1, 0, 7] * 4)
    out = gen.generate(labels, np.random.default_rng(7))

    rng = np.random.default_rng(7)                    # reference loop
    n = len(labels)
    ref = np.empty((n, 32, 32, 3), np.float32)
    shifts = rng.integers(-4, 5, size=(n, 2))
    eps = rng.normal(0, 0.3, size=ref.shape).astype(np.float32)
    for i, c in enumerate(labels):
        p = (0.6 * _coarse_pattern("cifar10", int(c))
             + 0.4 * 0.4 * _fine_pattern("cifar10", int(c)))
        p = np.roll(p, shifts[i], axis=(0, 1))
        ref[i] = np.clip(0.8 * p + eps[i], -1, 1)
    np.testing.assert_array_equal(out, ref)
    assert out.dtype == np.float32
    # empty schedule stays well-formed
    assert gen.generate(np.array([], np.int32),
                        np.random.default_rng(0)).shape == (0, 32, 32, 3)


def test_oracle_generator_labels():
    gen = OracleGenerator("cifar10", noise=0.1)
    rng = np.random.default_rng(0)
    labels = np.array([0] * 16 + [5] * 16)
    imgs = gen.generate(labels, rng)
    assert imgs.shape == (32, 32, 32, 3)
    # class means must separate (well beyond the shift/noise jitter)
    m0 = imgs[:16].mean(0)
    m5 = imgs[16:].mean(0)
    within = ((imgs[:16] - m0) ** 2).mean()
    between = ((m0 - m5) ** 2).mean()
    assert between > 0.25 * within


@pytest.mark.parametrize("strategy", ["genfv", "fedavg", "fl_only",
                                      "aigc_only", "fedprox"])
def test_runner_strategies(strategy):
    r = GenFVRunner(RunConfig(strategy=strategy, **FAST), fl_cfg=FAST_CFG)
    res = r.train()
    assert len(res.logs) == 2
    for log in res.logs:
        assert np.isfinite(log.loss)
        assert 0.0 <= log.accuracy <= 1.0
        if strategy == "genfv":
            assert 0.0 <= log.kappa2 <= 1.0
        if strategy in ("fl_only", "fedavg"):
            assert log.kappa2 == 0.0


def test_round_ledger_consistent():
    r = GenFVRunner(RunConfig(**FAST), fl_cfg=FAST_CFG)
    log = r.run_round(0)
    assert log.t_bar >= 0.0
    assert log.b_gen >= 0
    assert log.selected >= 0


def test_all_strategies_enumerated():
    assert set(STRATEGIES) == {"genfv", "fedavg", "no_emd", "madca", "ocean",
                               "fl_only", "aigc_only", "fedprox"}


def test_fedprox_proximal_pull():
    """FedProx's proximal term must shrink local drift from the anchor."""
    import jax
    import jax.numpy as jnp
    from repro.configs.genfv_cifar import cnn_config
    from repro.fl.client import client_update
    from repro.models.cnn import init_cnn
    cfg = cnn_config("cifar10", 0.125)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    imgs, labels = make_image_dataset("cifar10", 128, seed=0)
    p1, _ = client_update(params, cfg, imgs, labels,
                          np.random.default_rng(0), 3, 16, 5e-2)
    p2, _ = client_update(params, cfg, imgs, labels,
                          np.random.default_rng(0), 3, 16, 5e-2, prox_mu=0.5)

    def drift(p):
        return sum(float(jnp.sum(jnp.square(a - b))) for a, b in
                   zip(jax.tree.leaves(p), jax.tree.leaves(params)))

    assert drift(p2) < drift(p1)

"""DDPM (eq. 1-2): forward process statistics, loss descent, sampler."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import DDPM, ddpm_loss, ddpm_sample, make_ddpm, q_sample

TINY = DDPM(timesteps=8, num_classes=4, base_width=8)


def test_q_sample_statistics():
    """Eq. (1) composed: x_t ~ N(sqrt(abar) x0, (1-abar) I)."""
    ddpm = DDPM(timesteps=100)
    key = jax.random.PRNGKey(0)
    x0 = jnp.ones((256, 32, 32, 3)) * 0.5
    t = jnp.full((256,), 99, jnp.int32)
    eps = jax.random.normal(key, x0.shape)
    xt = q_sample(ddpm, x0, t, eps)
    abar = float(ddpm.alpha_bars()[99])
    assert float(xt.mean()) == pytest.approx(0.5 * np.sqrt(abar), abs=0.02)
    assert float(xt.std()) == pytest.approx(np.sqrt(1 - abar) + 0.0, abs=0.05)


def test_alpha_bars_monotone():
    ab = np.asarray(TINY.alpha_bars())
    assert np.all(np.diff(ab) < 0) and ab[0] < 1.0 and ab[-1] > 0.0


def test_loss_decreases_with_training():
    key = jax.random.PRNGKey(0)
    params = make_ddpm(key, TINY)
    x0 = jax.random.uniform(jax.random.PRNGKey(1), (16, 32, 32, 3),
                            minval=-1, maxval=1)
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 4)

    @jax.jit
    def step(p, k):
        loss, g = jax.value_and_grad(ddpm_loss, argnums=0)(p, TINY, k, x0, y)
        # lr 2e-2 / 40 steps: at lr 1e-3 x 20 the loss trend stays below the
        # per-step noise of resampled diffusion timesteps and the assertion
        # is vacuous (flaky-red on CPU)
        p = jax.tree.map(lambda w, gg: w - 2e-2 * gg, p, g)
        return p, loss

    losses = []
    k = jax.random.PRNGKey(3)
    for i in range(40):
        k, ks = jax.random.split(k)
        params, l = step(params, ks)
        losses.append(float(l))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_sampler_shapes_and_range():
    params = make_ddpm(jax.random.PRNGKey(0), TINY)
    out = ddpm_sample(params, TINY, jax.random.PRNGKey(1),
                      np.array([0, 1, 2, 3]))
    assert out.shape == (4, 32, 32, 3)
    assert float(out.min()) >= -1.0 and float(out.max()) <= 1.0
    assert bool(jnp.isfinite(out).all())

"""Tier-1 tests for `repro.obs` — the span/event tracer, metrics registry
and sinks, plus the two hard invariants of the observability layer:

* **bitwise no-perturbation** — attaching an `Obs` tracer to a runner or
  sweep never changes a single RoundLog field relative to the NULL_OBS
  run, on both planner backends, with and without fault schedules;
* **trace validity** — every exported trace.json is Chrome/Perfetto
  loadable: spans closed, non-negative timestamps/durations, compile vs
  execute stages tagged.

Also hosts the library print-lint (structured obs logging replaced the
bare prints; `launch/` CLIs are exempt) and the null-path overhead smoke.
"""
from __future__ import annotations

import functools
import io
import json
import os
import re
import time

import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl.rounds import GenFVRunner, RunConfig, run_payload
from repro.obs import (METRICS_SCHEMA, MetricsRegistry, NULL_OBS, NullObs,
                       Obs, ProgressLogger, Stopwatch, list_metrics_artifacts,
                       load_metrics_artifact, log_line, save_metrics_artifact,
                       stopwatch)
from repro.obs.trace import _NULL_SPAN

FAST = dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


class FakeClock:
    """Deterministic monotone clock: every read advances by `step`."""

    def __init__(self, step: float = 1.0):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# Metrics registry.
# ---------------------------------------------------------------------------
def test_registry_counters_gauges_dists():
    m = MetricsRegistry()
    m.count("a")
    m.count("a", 2)
    m.count("a", 1, phase="x")                  # different tags: own key
    m.gauge("g", 5.0)
    m.gauge("g", 7.0)                           # last write wins
    for v in (3.0, 1.0, 2.0):
        m.observe("d", v)
    assert m.counter_value("a") == 3
    assert m.counter_value("a", phase="x") == 1
    assert m.counter_value("missing") == 0
    assert m.gauge_value("g") == 7.0
    assert m.gauge_value("missing", default=-1) == -1
    p = m.payload()
    (d,) = p["dists"]
    assert d == {"name": "d", "tags": {}, "n": 3, "sum": 6.0,
                 "min": 1.0, "max": 3.0}


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.count("c", 1)
    b.count("c", 2)
    a.gauge("g", 1.0)
    b.gauge("g", 9.0)
    a.observe("d", 1.0)
    b.observe("d", 5.0)
    a.merge(b)
    assert a.counter_value("c") == 3
    assert a.gauge_value("g") == 9.0            # other's gauges overwrite
    (d,) = a.payload()["dists"]
    assert (d["n"], d["sum"], d["min"], d["max"]) == (2, 6.0, 1.0, 5.0)


def test_registry_payload_json_ready():
    m = MetricsRegistry()
    m.count("z", tag="t")
    m.count("a")
    m.observe("d", 1.5, stage="compile")
    p = json.loads(json.dumps(m.payload()))     # scalar leaves only
    assert [r["name"] for r in p["counters"]] == ["a", "z"]   # sorted


# ---------------------------------------------------------------------------
# Stopwatch / progress logging.
# ---------------------------------------------------------------------------
def test_stopwatch_live_and_frozen():
    clk = FakeClock(step=1.0)
    with stopwatch(clock=clk) as sw:
        live = sw.elapsed_s                     # one clock read: 1.0
    frozen = sw.elapsed_s
    assert live == 1.0
    assert frozen == 2.0                        # exit read froze it
    assert sw.elapsed_s == frozen               # no more clock reads
    assert isinstance(sw, Stopwatch)


def test_progress_logger_rate_limit_and_force():
    out = io.StringIO()
    clk = FakeClock(step=0.01)                  # 10ms between reads
    pl = ProgressLogger(min_interval_s=0.1, clock=clk, out=out)
    wrote = [pl.emit("k", f"line{i}") for i in range(5)]
    assert wrote[0] and not any(wrote[1:])      # throttled after the first
    assert pl.emit("other", "x")                # per-key, not global
    assert pl.emit("k", "final", force=True)    # force bypasses the limit
    assert out.getvalue().splitlines() == ["line0", "x", "final"]


def test_log_line_records_event_and_renders(capsys):
    obs = Obs(clock=FakeClock(), meta={})
    log_line(obs, "train/x", "round 0 acc=0.1", force=True,
             round=0, accuracy=0.1)
    (ev,) = obs.events
    assert ev["name"] == "log" and ev["tags"]["accuracy"] == 0.1
    log_line(NULL_OBS, "train/x", "null path ok", force=True)
    out = capsys.readouterr().out
    assert "round 0 acc=0.1" in out and "null path ok" in out


# ---------------------------------------------------------------------------
# Span mechanics.
# ---------------------------------------------------------------------------
def test_span_compile_execute_tagging():
    obs = Obs(clock=FakeClock())
    for _ in range(2):
        with obs.span("phase", key=4):
            pass
    with obs.span("phase", key=8):              # new jit key: compiles again
        pass
    with obs.span("untracked"):                 # key=None: never "compile"
        pass
    stages = [e["stage"] for e in obs.events]
    assert stages == ["compile", "execute", "compile", "execute"]
    assert obs.metrics.payload()["dists"] == [
        {"name": "span/phase", "tags": {"stage": "compile"}, "n": 2,
         "sum": pytest.approx(2.0), "min": 1.0, "max": 1.0},
        {"name": "span/phase", "tags": {"stage": "execute"}, "n": 1,
         "sum": 1.0, "min": 1.0, "max": 1.0},
        {"name": "span/untracked", "tags": {"stage": "execute"}, "n": 1,
         "sum": 1.0, "min": 1.0, "max": 1.0}]


def test_span_nesting_and_open_count():
    obs = Obs(clock=FakeClock())
    with obs.span("outer"):
        assert obs.open_spans == 1
        with obs.span("inner"):
            assert obs.open_spans == 2
    assert obs.open_spans == 0
    # inner closes first, so it is appended first
    assert [e["name"] for e in obs.events] == ["inner", "outer"]


def test_tagged_view_merges_tags():
    obs = Obs(clock=FakeClock())
    cell = obs.tagged(cell=3)
    with cell.span("round/plan", round=1):
        pass
    cell.count("planner/rounds")
    cell.event("log", text="x")
    assert obs.events[0]["tags"] == {"cell": 3, "round": 1}
    assert obs.metrics.counter_value("planner/rounds", cell=3) == 1
    nested = cell.tagged(round=9)
    nested.gauge("g", 1.0)
    assert obs.metrics.gauge_value("g", cell=3, round=9) == 1.0


def test_null_obs_surface():
    assert isinstance(NULL_OBS, NullObs) and not NULL_OBS.enabled
    sp = NULL_OBS.span("anything", key=1, tag="x")
    assert sp is _NULL_SPAN                     # one shared no-op span
    with sp as s:
        s.sync = object()                       # swallowed, never read
    NULL_OBS.count("c", 5)
    NULL_OBS.gauge("g", 1.0)
    NULL_OBS.observe("d", 2.0)
    NULL_OBS.event("e", k=1)
    assert NULL_OBS.tagged(cell=1) is NULL_OBS  # no per-cell allocation


def test_null_obs_overhead_smoke():
    """The disabled path must stay in no-op territory: 50k span + metric
    call groups well under a second (generous bound for slow CI hosts)."""
    t0 = time.perf_counter()
    for _ in range(50_000):
        with NULL_OBS.span("round/plan", key=4, round=1):
            pass
        NULL_OBS.count("planner/rounds")
        NULL_OBS.observe("round/t_round", 0.5)
    assert time.perf_counter() - t0 < 2.0


# ---------------------------------------------------------------------------
# Sinks: metrics artifact, JSONL, Chrome/Perfetto trace.
# ---------------------------------------------------------------------------
def _sample_obs() -> Obs:
    obs = Obs(clock=FakeClock(), meta={"spec": "unit"})
    with obs.span("round/plan", key=4, round=0):
        with obs.span("round/select", round=0):
            pass
    obs.event("log", text="hello")
    with obs.span("round/plan", key=4, round=1, cell=2):
        pass
    obs.count("planner/rounds", 2)
    obs.gauge("fleet/bucket", 4)
    return obs


def test_metrics_artifact_roundtrip(tmp_path):
    obs = _sample_obs()
    path = obs.save_metrics("unit", directory=str(tmp_path))
    assert list_metrics_artifacts(str(tmp_path)) == [path]
    doc = load_metrics_artifact(path)
    assert doc["schema"] == METRICS_SCHEMA
    assert doc["meta"] == {"spec": "unit"}
    assert doc["open_spans"] == 0 and doc["events"] == 4
    assert {"backend", "jax", "platform"} <= set(doc["host"])
    names = {c["name"] for c in doc["counters"]}
    assert "planner/rounds" in names
    assert any(d["name"] == "span/round/plan" for d in doc["dists"])


def test_metrics_artifact_schema_guard(tmp_path):
    bad = tmp_path / "x.metrics.json"
    bad.write_text(json.dumps({"schema": "something/else"}))
    with pytest.raises(ValueError, match="not a"):
        load_metrics_artifact(str(bad))
    with pytest.raises(ValueError, match="schema"):
        save_metrics_artifact({"schema": "wrong"}, "x",
                              directory=str(tmp_path))


def test_write_jsonl(tmp_path):
    obs = _sample_obs()
    path = obs.write_jsonl(str(tmp_path / "events.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["schema"] == "repro.obs/events/v1"
    assert len(lines) == 1 + len(obs.events)
    assert {l["ph"] for l in lines[1:]} == {"X", "i"}


def test_trace_schema(tmp_path):
    obs = _sample_obs()
    path = obs.write_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(path))                 # Perfetto-loadable JSON
    assert doc["otherData"]["schema"] == "repro.obs/trace/v1"
    evs = doc["traceEvents"]
    assert evs and all(e["ts"] >= 0 for e in evs)
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(e["dur"] >= 0 for e in xs)
    # spans are appended at close: their end timestamps are monotone
    ends = [e["ts"] + e["dur"] for e in xs]
    assert ends == sorted(ends)
    assert all(e["s"] == "t" for e in evs if e["ph"] == "i")
    # the sweep-cell tag routes to its own track; untagged events share 0
    assert {e["tid"] for e in xs} == {0, 3}
    assert {e["args"]["stage"] for e in xs} == {"compile", "execute"}


def test_trace_refuses_open_spans(tmp_path):
    obs = Obs(clock=FakeClock())
    span = obs.span("dangling")
    span.__enter__()
    with pytest.raises(ValueError, match="open"):
        obs.write_trace(str(tmp_path / "trace.json"))


# ---------------------------------------------------------------------------
# RunConfig plumbing.
# ---------------------------------------------------------------------------
def test_runconfig_obs_field_is_execution_machinery():
    plain = RunConfig(**FAST)
    traced = RunConfig(obs=Obs(clock=FakeClock()), **FAST)
    assert plain == traced                      # compare=False: same cell
    payload = run_payload(traced)
    assert "obs" not in payload
    json.dumps(payload)                         # checkpoint/spec-safe


# ---------------------------------------------------------------------------
# Runner integration: the bitwise no-perturbation invariant + metrics
# content. The traced runs are cached so the parity, ledger and trace
# tests share one training per (planner, faults) combination.
# ---------------------------------------------------------------------------
def _run_cfg(planner: str, faults: str | None) -> RunConfig:
    return RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                     planner=planner, faults=faults, **FAST)


@functools.lru_cache(maxsize=None)
def _traced(planner: str, faults: str | None):
    obs = Obs(meta={"test": "obs", "planner": planner})
    res = GenFVRunner(_run_cfg(planner, faults), fl_cfg=FAST_CFG,
                      obs=obs).train()
    return obs, res


@pytest.mark.parametrize("planner", ["jax", "numpy"])
@pytest.mark.parametrize("faults", [None, "mixed_stress"])
def test_runner_obs_bitwise_no_perturbation(planner, faults):
    """The hard invariant: an attached tracer only *reads* host values, so
    every RoundLog field — including float curves — is bitwise identical
    to the NULL_OBS run, on both planner backends, faulted or not."""
    _, traced = _traced(planner, faults)
    plain = GenFVRunner(_run_cfg(planner, faults), fl_cfg=FAST_CFG).train()
    assert len(plain.logs) == FAST["rounds"]
    for a, b in zip(plain.logs, traced.logs):
        assert a == b                           # every field, bitwise


def test_runner_obs_bitwise_ddpm_generate_path(monkeypatch, tmp_path):
    """Same invariant on the AIGC dataplane: the tracer's span around the
    batched sampling dispatch (`round/generate/sample`) and its gen
    counters are read-only, so a ddpm run is bitwise identical with and
    without an attached Obs — and the span actually fires."""
    import repro.gen.service as gen_service
    from repro.gen.calib import CALIB_BUCKET, _calib_key, save_calibration
    from repro.diffusion.ddpm import DDPM

    for k, v in (("RUNNER_TIMESTEPS", 8), ("RUNNER_BASE_WIDTH", 8),
                 ("PRETRAIN_STEPS", 2), ("PRETRAIN_REF", 64)):
        monkeypatch.setattr(gen_service, k, v)
    monkeypatch.setenv("REPRO_ARTIFACTS", str(tmp_path / "artifacts"))
    ddpm = DDPM(timesteps=8, num_classes=10, base_width=8)
    save_calibration({_calib_key(ddpm, 2, CALIB_BUCKET):
                      {"t_image": 0.05, "bucket": CALIB_BUCKET,
                       "sampler_steps": 2}})

    run = RunConfig(strategy="genfv", seed=0, generator="ddpm",
                    sampler_steps=2, **FAST)
    obs = Obs(meta={"test": "obs-gen"})
    traced = GenFVRunner(run, fl_cfg=FAST_CFG, obs=obs).train()
    plain = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    assert len(plain.logs) == FAST["rounds"]
    for a, b in zip(plain.logs, traced.logs):
        assert a == b                           # every field, bitwise
    gen_rounds = sum(1 for l in traced.logs if l.b_gen > 0)
    assert gen_rounds > 0
    spans = [d for d in obs.metrics.payload()["dists"]
             if d["name"] == "span/round/generate/sample"]
    assert spans and sum(d["n"] for d in spans) == gen_rounds
    assert obs.metrics.counter_value("gen/images") == \
        sum(int(l.b_gen) for l in traced.logs)


def test_roundlog_carries_planner_convergence():
    _, res = _traced("jax", None)
    for log in res.logs:
        assert log.bcd_iters >= 1
        assert log.planner_converged in (0, 1)


def test_checkpoint_roundtrips_planner_fields(tmp_path):
    run = _run_cfg("jax", None)
    r = GenFVRunner(run, fl_cfg=FAST_CFG)
    r.run_round(0)
    path = str(tmp_path / "runner.npz")
    r.save_checkpoint(path)
    fresh = GenFVRunner(run, fl_cfg=FAST_CFG)
    assert fresh.load_checkpoint(path) == 1
    assert fresh.logs == r.logs                 # bcd_iters etc. included


def test_runner_metrics_planner_counters():
    obs, res = _traced("jax", None)
    m = obs.metrics
    assert m.counter_value("planner/rounds", planner="jax") == FAST["rounds"]
    converged = m.counter_value("planner/converged", planner="jax")
    assert converged == sum(l.planner_converged for l in res.logs)
    payload = m.payload()
    dists = {(d["name"], d["tags"].get("stage")) for d in payload["dists"]}
    for phase in ("round/fleet", "round/select", "round/plan",
                  "round/local_sgd", "round/generate", "round/aggregate",
                  "round/eval"):
        assert any(n == f"span/{phase}" for n, _ in dists), phase
    # the first jitted plan is traced+compiled; every round is accounted
    assert ("span/round/plan", "compile") in dists
    assert sum(d["n"] for d in payload["dists"]
               if d["name"] == "span/round/plan") == FAST["rounds"]
    # world gauges (scenario fleets come from the persistent world)
    assert m.gauge_value("world/population") is not None


def test_runner_metrics_fault_ledger():
    obs, res = _traced("jax", "mixed_stress")
    m = obs.metrics
    for key in ("late", "rejected", "stale_merged", "dropped"):
        assert m.counter_value(f"faults/{key}") == res.curve(key).sum()
    d = next(d for d in m.payload()["dists"]
             if d["name"] == "round/t_round")
    assert d["n"] == FAST["rounds"]


def test_runner_trace_emission(tmp_path):
    obs, _ = _traced("jax", None)
    assert obs.open_spans == 0
    path = obs.write_trace(str(tmp_path / "trace.json"))
    doc = json.load(open(path))
    stages = {e["args"].get("stage") for e in doc["traceEvents"]
              if e["ph"] == "X"}
    assert {"compile", "execute"} <= stages
    obs.write_jsonl(str(tmp_path / "events.jsonl"))


# ---------------------------------------------------------------------------
# Sweep integration: the ISSUE acceptance grid — 8 cells with obs enabled
# emit a loadable trace + metrics artifact while staying bitwise identical
# to the untraced sweep.
# ---------------------------------------------------------------------------
SWEEP_FAST = dict(rounds=2, train_size=200, test_size=32, width_mult=0.0625)


def _sweep_spec() -> ExperimentSpec:
    return ExperimentSpec(name="obs-accept",
                          strategies=("genfv", "fl_only"),
                          scenarios=("rush_hour", "highway_free_flow"),
                          seeds=(0, 1),
                          base=RunConfig(**SWEEP_FAST))


def test_sweep_obs_emission_and_parity(tmp_path):
    spec = _sweep_spec()
    assert spec.n_cells == 8
    obs = Obs(meta={"spec": spec.name})
    traced = Sweep(spec, fl_cfg=FAST_CFG, obs=obs).run()
    plain = Sweep(spec, fl_cfg=FAST_CFG).run()

    # bitwise parity across the whole grid, incl. the new planner metrics
    assert {"bcd_iters", "planner_converged"} <= set(plain.metrics)
    for k in plain.metrics:
        np.testing.assert_array_equal(traced.metrics[k], plain.metrics[k],
                                      err_msg=k)

    # emission: Perfetto-loadable trace with per-cell tracks + stages
    trace = json.load(open(obs.write_trace(str(tmp_path / "trace.json"))))
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert {e["args"]["stage"] for e in xs} == {"compile", "execute"}
    cell_tracks = {e["tid"] for e in xs if e["tid"] > 0}
    assert cell_tracks == set(range(1, 9))      # all 8 cells traced

    # metrics artifact with planner convergence counters + sweep gauges
    doc = load_metrics_artifact(
        obs.save_metrics(spec.name, directory=str(tmp_path)))
    m = obs.metrics
    assert m.gauge_value("sweep/cells") == 8
    assert m.gauge_value("sweep/planner_dispatches") is not None
    per_cell = sum(m.counter_value("planner/rounds", cell=c, planner="jax")
                   for c in range(8))
    assert per_cell == 8 * SWEEP_FAST["rounds"]
    assert any(c["name"] == "planner/converged" for c in doc["counters"])
    assert any(d["name"].startswith("span/sweep/plan_batched")
               for d in doc["dists"])


# ---------------------------------------------------------------------------
# Library print-lint: structured obs logging only (launch/ CLIs exempt).
# ---------------------------------------------------------------------------
_PRINT_RE = re.compile(r"(?<![\w.])print\(")


def test_no_bare_print_in_library():
    offenders = []
    for dirpath, dirnames, files in os.walk(SRC_ROOT):
        dirnames[:] = [d for d in dirnames
                       if d not in ("launch", "__pycache__")]
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                for i, line in enumerate(f, 1):
                    code = line.split("#", 1)[0]
                    if _PRINT_RE.search(code):
                        offenders.append(
                            f"{os.path.relpath(path, SRC_ROOT)}:{i}")
    assert not offenders, (
        "bare print( in library code — route it through "
        f"repro.obs.log_line / ProgressLogger instead: {offenders}")


# ---------------------------------------------------------------------------
# Clock-discipline lint: the FL round loop and the serving engine must run
# on injectable clocks only (VirtualClock / the Obs clock parameter) so the
# streaming determinism contract (fl/stream.py) can't silently regress.
# ---------------------------------------------------------------------------
_WALLCLOCK_RE = re.compile(r"(?<![\w.])time\.(time|monotonic)\(")


def test_no_wall_clock_in_streaming_paths():
    offenders = []
    for sub in ("fl", "serve"):
        for dirpath, dirnames, files in os.walk(os.path.join(SRC_ROOT, sub)):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in files:
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as f:
                    for i, line in enumerate(f, 1):
                        code = line.split("#", 1)[0]
                        if _WALLCLOCK_RE.search(code):
                            offenders.append(
                                f"{os.path.relpath(path, SRC_ROOT)}:{i}")
    assert not offenders, (
        "time.time()/time.monotonic() in a deterministic streaming path — "
        f"inject a VirtualClock (repro.obs) instead: {offenders}")

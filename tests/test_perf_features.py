"""Beyond-paper perf features must preserve semantics: sorted/expert-parallel
MoE == dense MoE, vocab padding == unpadded loss, chunked CE == direct CE,
analytic roofline model consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.analysis import executed_bytes, executed_flops
from repro.models import api
from repro.models.moe import init_moe, moe_dense, moe_sorted
from repro.models.transformer import chunked_xent, forward, loss_fn, unembed


@pytest.mark.parametrize("groups", [1, 4])
def test_sorted_moe_equals_dense(groups):
    cfg = get_config("olmoe-1b-7b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    yd, auxd = moe_dense(p, x, cfg)
    ys, auxs = moe_sorted(p, x, cfg, capacity_factor=4.0, n_groups=groups)
    assert float(jnp.abs(yd - ys).max()) < 1e-5
    assert float(jnp.abs(auxd - auxs)) < 1e-5


def test_sorted_moe_drops_overflow_gracefully():
    cfg = get_config("olmoe-1b-7b").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    # tiny capacity: output must stay finite and bounded by dense magnitude
    y, _ = moe_sorted(p, x, cfg, capacity_factor=0.25)
    yd, _ = moe_dense(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(y).max()) <= float(jnp.abs(yd).max()) * 3 + 1.0


def test_vocab_padding_identical_loss():
    cfg = get_config("qwen1.5-0.5b").reduced()
    cfgp = dataclasses.replace(cfg, pad_vocab_multiple=128)
    assert cfgp.padded_vocab_size % 128 == 0
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    paramsp = api.init_params(jax.random.PRNGKey(0), cfgp)
    paramsp["embed"] = paramsp["embed"].at[:cfg.vocab_size].set(params["embed"])
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                      cfg.vocab_size),
         "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                       cfg.vocab_size),
         "mask": jnp.ones((2, 16))}
    l1, _ = loss_fn(params, cfg, b)
    l2, _ = loss_fn(paramsp, cfgp, b)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_padded_logits_masked():
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b").reduced(),
                              pad_vocab_multiple=100)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    b = {"tokens": jnp.zeros((1, 4), jnp.int32)}
    hid, _, _ = forward(params, cfg, b, logits_mode="hidden")
    logits = unembed(params, cfg, hid)
    assert logits.shape[-1] == cfg.padded_vocab_size
    assert float(logits[..., cfg.vocab_size:].max()) <= -1e8


def test_chunked_xent_matches_direct():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 40
    hid = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.1
    tgt = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (B, S)) > 0.3).astype(
        jnp.float32)
    loss_c = chunked_xent(params, cfg, hid, tgt, mask, chunk=16)
    logits = unembed(params, cfg, hid)
    ll = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(ll, tgt[..., None], -1)[..., 0]
    loss_d = jnp.sum(ce * mask) / jnp.sum(mask)
    assert abs(float(loss_c) - float(loss_d)) < 1e-4


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "olmoe-1b-7b",
                                  "recurrentgemma-9b", "whisper-tiny",
                                  "llava-next-mistral-7b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_analytic_model_sane(arch, shape):
    cfg = get_config(arch)
    s = INPUT_SHAPES[shape]
    f = executed_flops(cfg, s)
    b = executed_bytes(cfg, s)
    assert f["total"] > 0 and b["total"] > 0
    assert all(v >= 0 for v in f["breakdown"].values())
    # executed >= useful model flops (overcompute never helps)
    n = cfg.active_param_count()
    toks = s.global_batch * (s.seq_len if s.kind != "decode" else 1)
    model = (6 if s.kind == "train" else 2) * n * toks
    assert f["total"] >= 0.6 * model   # allow head-count approximations


def test_sorted_moe_cheaper_than_dense_in_model():
    cfg = get_config("olmoe-1b-7b")
    s = INPUT_SHAPES["train_4k"]
    dense = executed_flops(cfg, s, moe_mode="dense")["total"]
    sorted_ = executed_flops(cfg, s, moe_mode="sorted")["total"]
    assert sorted_ < 0.45 * dense

"""Serving-path invariant: prefill + one-token decode steps reproduce the
full-sequence forward logits for every cache family (ring-buffer KV,
RG-LRU state, xLSTM matrix memory, enc-dec cross attention)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.transformer import (_group_split, build_cross_kv, encode,
                                      forward, unembed)

FAMILIES = ["qwen1.5-0.5b", "gemma2-9b", "xlstm-1.3b", "recurrentgemma-9b",
            "olmoe-1b-7b", "whisper-tiny", "gemma-2b"]


def _full_logits(params, cfg, batch):
    hid, _, _ = forward(params, cfg, batch, logits_mode="hidden")
    return unembed(params, cfg, hid)


def _attach_cross(params, cfg, cache, frames):
    enc = encode(params, cfg, frames)
    ckv = build_cross_kv(params, cfg, enc)
    G, rem = _group_split(cfg)
    if G > 0:
        for i in range(len(cfg.pattern)):
            cache["groups"][i]["cross_kv"] = ckv["groups"][i]
    for i in range(len(rem)):
        cache["rem"][i]["cross_kv"] = ckv["rem"][i]
    return cache


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, cfg)
    B, S = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.modality == "audio":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, cfg.encoder_seq, cfg.d_model))
    full = _full_logits(params, cfg, batch)

    cache = api.init_cache(cfg, B, S + 4)
    if cfg.modality == "audio":
        cache = _attach_cross(params, cfg, cache, batch["frames"])
    prefill = jax.jit(api.make_prefill_step(cfg))
    decode = jax.jit(api.make_decode_step(cfg))

    Sp = S - 4
    logits, cache = prefill(params, cache, {"tokens": toks[:, :Sp]})
    assert jnp.abs(logits - full[:, Sp - 1]).max() < 2e-4
    for t in range(Sp, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = decode(params, cache, toks[:, t:t + 1], pos)
        assert jnp.abs(logits - full[:, t]).max() < 2e-4, (arch, t)


def test_ring_buffer_wraparound():
    """Local-attention cache smaller than the sequence: decode must agree
    with full forward thanks to position-based masking."""
    cfg = get_config("gemma2-9b").reduced()
    assert cfg.sliding_window is not None
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full = _full_logits(params, cfg, {"tokens": toks})

    cache = api.init_cache(cfg, B, S)   # local layers ring at sliding_window
    prefill = jax.jit(api.make_prefill_step(cfg))
    decode = jax.jit(api.make_decode_step(cfg))
    logits, cache = prefill(params, cache, {"tokens": toks[:, :8]})
    for t in range(8, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = decode(params, cache, toks[:, t:t + 1], pos)
    assert jnp.abs(logits - full[:, -1]).max() < 2e-4


def test_greedy_generate_runs():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = api.greedy_generate(cfg, params, prompt, steps=4)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())

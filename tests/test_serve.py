"""Serving engine: continuous batching with heterogeneous admission must
produce exactly the same tokens as isolated single-request generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reference(cfg, params, prompt, n):
    out = api.greedy_generate(cfg, params, jnp.asarray(prompt)[None], steps=n,
                              max_len=64)
    return [int(t) for t in out[0]]


def test_single_request_matches_reference(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    prompt = np.arange(5, 13) % cfg.vocab_size
    eng.submit(Request(0, prompt, max_new_tokens=6))
    done = eng.run()
    assert len(done) == 1 and done[0].done
    assert done[0].out == _reference(cfg, params, prompt, 6)


def test_continuous_batching_heterogeneous(model):
    """Requests of different prompt lengths / budgets, more requests than
    slots — every output must equal its isolated reference."""
    cfg, params = model
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=p), max_new_tokens=n)
            for i, (p, n) in enumerate([(6, 5), (11, 8), (4, 3), (9, 6), (7, 4)])]
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs)
    for r in reqs:
        assert r.out == _reference(cfg, params, r.prompt, r.max_new_tokens), r.rid


def test_slot_reuse(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, max_len=64)
    p1 = np.arange(4)
    p2 = np.arange(10, 16)
    eng.submit(Request(0, p1, max_new_tokens=3))
    eng.submit(Request(1, p2, max_new_tokens=3))
    done = eng.run()
    assert [r.rid for r in done] == [0, 1]
    assert done[1].out == _reference(cfg, params, p2, 3)


def test_merge_lane_row_surgery_and_scalar_leaves():
    """_merge_lane's contract per leaf kind: batch-dim leaves get row
    surgery (only the target row changes), scalar leaves take the lane's
    value (pins the old `dst.ndim == 0 or ... and dst.ndim == 0`
    precedence confusion), and batch-free same-shape leaves are replaced."""
    from repro.serve.engine import _merge_lane
    cache = {"kv": jnp.arange(24, dtype=jnp.float32).reshape(2, 3, 4),
             "idx": jnp.array([5, 7]),             # per-row cursor
             "step": jnp.array(3),                 # scalar leaf
             "rope": jnp.arange(6.0).reshape(3, 2)}  # batch-free, same shape
    lane = {"kv": jnp.full((1, 3, 4), -1.0, jnp.float32),
            "idx": jnp.array([9]),
            "step": jnp.array(11),
            "rope": jnp.full((3, 2), 2.5)}
    out = _merge_lane(cache, lane, row=1)
    np.testing.assert_array_equal(np.asarray(out["kv"][0]),
                                  np.arange(12, dtype=np.float32).reshape(3, 4))
    assert (np.asarray(out["kv"][1]) == -1.0).all()
    assert np.asarray(out["idx"]).tolist() == [5, 9]
    assert int(out["step"]) == 11
    assert (np.asarray(out["rope"]) == 2.5).all()


def test_per_row_cache_cursor(model):
    """The per-row idx cursor: rows at different positions never clobber
    each other (the scalar-cursor bug this engine exposed)."""
    cfg, params = model
    cache = api.init_cache(cfg, 2, 32)
    idx_leaves = [l for p, l in jax.tree_util.tree_flatten_with_path(cache)[0]
                  if "idx" in jax.tree_util.keystr(p)]
    assert idx_leaves
    for l in idx_leaves:
        # per-row cursor: trailing dim is the batch (leading dim may be the
        # scan-group stack)
        assert l.shape[-1] == 2


def test_max_ticks_eviction_frees_slot(model):
    """A request whose decode never reaches its budget within the deadline
    is evicted; the freed slot serves later admissions (satellite: stuck
    requests must not occupy slots forever)."""
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=1, max_len=64, deadline_ticks=4)
    stuck = Request(0, np.arange(4), max_new_tokens=1000)   # can't finish
    nxt = Request(1, np.arange(10, 16), max_new_tokens=3)
    eng.submit(stuck)
    eng.submit(nxt)
    done = eng.run(max_ticks=50)
    assert [r.rid for r in done] == [0, 1]
    assert stuck.done and stuck.evicted
    # the evicted request got exactly prefill + deadline decode ticks
    assert len(stuck.out) == 1 + 4
    assert nxt.done and not nxt.evicted
    assert nxt.out == _reference(cfg, params, nxt.prompt, 3)


def test_per_request_deadline_overrides_engine_default(model):
    cfg, params = model
    eng = ServeEngine(cfg, params, slots=2, max_len=64, deadline_ticks=2)
    # per-request deadline wins over the engine default in both directions
    a = Request(0, np.arange(4), max_new_tokens=1000, deadline_ticks=5)
    b = Request(1, np.arange(6), max_new_tokens=3)   # finishes before 2? no:
    # 1 prefill token + 2 decode ticks == 3 tokens: completes AT the budget,
    # so completion wins and it is not marked evicted
    eng.submit(a)
    eng.submit(b)
    eng.run(max_ticks=50)
    assert a.evicted and len(a.out) == 1 + 5
    assert b.done and not b.evicted and len(b.out) == 3

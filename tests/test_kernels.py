"""Pallas kernel sweeps vs the pure-jnp oracles (interpret=True on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, rglru_scan_ref
from repro.kernels.rglru_scan import rglru_scan
from repro.models.attention import sdpa_chunked

ATTN_CASES = [
    # Sq, Skv, nq, nkv, hd, window, softcap, bq, bk
    (128, 128, 4, 2, 64, None, None, 64, 64),
    (64, 256, 8, 1, 64, None, None, 64, 128),      # MQA, decode-ish context
    (50, 130, 8, 2, 64, 32, 50.0, 64, 64),         # ragged + window + cap
    (1, 256, 4, 4, 128, None, 30.0, 128, 128),     # single-token decode
    (256, 256, 2, 2, 32, 64, None, 128, 64),
    (33, 65, 6, 3, 64, 16, None, 32, 32),
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    Sq, Skv, nq, nkv, hd, win, cap, bq, bk = case
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(2, Sq, nq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(2, Skv, nkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(2, Skv, nkv, hd)), dtype)
    q_pos = jnp.arange(Skv - Sq, Skv)[None].repeat(2, 0)
    kv_pos = jnp.arange(Skv)[None].repeat(2, 0)
    out = flash_attention(q, k, v, q_pos, kv_pos, window=win, softcap=cap,
                          block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, q_pos, kv_pos, window=win, softcap=cap)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert out.shape == ref.shape == (2, Sq, nq, hd)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < tol


def test_flash_attention_empty_slots():
    """Cache slots with pos = -1 (empty ring-buffer lanes) never attend."""
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 4, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.float32)
    kv_pos = jnp.where(jnp.arange(64) < 10, jnp.arange(64), -1)[None]
    q_pos = jnp.arange(6, 10)[None]
    out = flash_attention(q, k, v, q_pos, kv_pos, block_q=4, block_k=32)
    # zero out v beyond slot 10 must not change anything
    v2 = v.at[:, 10:].set(1e6)
    out2 = flash_attention(q, k, v2, q_pos, kv_pos, block_q=4, block_k=32)
    assert float(jnp.abs(out - out2).max()) < 1e-5


@pytest.mark.parametrize("shape", [(2, 64, 32), (1, 100, 70), (3, 17, 5),
                                   (2, 256, 128)])
@pytest.mark.parametrize("blocks", [(16, 16), (64, 64), (32, 128)])
def test_rglru_scan_vs_ref(shape, blocks):
    B, S, W = shape
    bt, bw = blocks
    rng = np.random.default_rng(B * S * W)
    la = jnp.asarray(-np.abs(rng.normal(size=shape)), jnp.float32)
    b = jnp.asarray(rng.normal(size=shape), jnp.float32)
    out = rglru_scan(la, b, block_t=bt, block_w=bw)
    ref = rglru_scan_ref(la, b)
    assert float(jnp.abs(out - ref).max()) < 1e-5


def test_sdpa_chunked_vs_ref_sweep():
    """The XLA-native double-blocked SDPA (dry-run path) against the oracle."""
    rng = np.random.default_rng(1)
    for (Sq, Skv, nq, nkv, hd, win, cap) in [
            (17, 33, 4, 2, 16, None, None), (64, 64, 8, 1, 32, 16, 50.0),
            (1, 40, 4, 4, 8, None, 30.0)]:
        q = jnp.asarray(rng.normal(size=(2, Sq, nq, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, Skv, nkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, Skv, nkv, hd)), jnp.float32)
        q_pos = jnp.arange(Skv - Sq, Skv)[None].repeat(2, 0)
        kv_pos = jnp.arange(Skv)[None].repeat(2, 0)
        out = sdpa_chunked(q, k, v, q_pos, kv_pos, window=win,
                           attn_softcap=cap, kv_chunk=16, q_chunk=8)
        ref = flash_attention_ref(q, k, v, q_pos, kv_pos, window=win,
                                  softcap=cap)
        assert float(jnp.abs(out - ref).max()) < 5e-6


def test_mlstm_chunkwise_equals_recurrent():
    from repro.models.xlstm import _mlstm_cell_step, mlstm_seq
    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 37, 3, 8
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
               for _ in range(3))
    it = jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32)
    ft = jnp.asarray(np.log(1 / (1 + np.exp(-rng.normal(size=(B, S, H))))),
                     jnp.float32)
    state = (jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)),
             jnp.zeros((B, H)))
    C, n, m = state
    hs_ref = []
    for t in range(S):
        (C, n, m), h = _mlstm_cell_step(
            (C, n, m), (q[:, t], k[:, t], v[:, t], it[:, t], ft[:, t]))
        hs_ref.append(h)
    hs_ref = jnp.stack(hs_ref, 1)
    for chunk in (8, 16, 37):
        hs, (C2, n2, m2) = mlstm_seq(q, k, v, it, ft, state, chunk=chunk)
        assert float(jnp.abs(hs - hs_ref).max()) < 1e-4
        assert float(jnp.abs(C2 - C).max()) < 1e-4


# ---------------------------------------------------------------------------
# Property sweep: random shapes, flash kernel vs oracle
# (hypothesis is optional in the image; the fixed-case sweeps above still run)
# ---------------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st  # noqa: E402
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @given(st.integers(1, 3), st.integers(1, 48), st.integers(1, 64),
           st.sampled_from([(1, 1), (2, 1), (4, 2), (4, 4)]),
           st.sampled_from([16, 32, 64]),
           st.sampled_from([None, 8, 24]))
    @settings(max_examples=12, deadline=None)
    def test_flash_attention_property(B, Sq, Skv, heads, hd, win):
        import numpy as _np
        nq, nkv = heads
        Sq = min(Sq, Skv)               # causal decode-style alignment
        rng = _np.random.default_rng(B * 1000 + Sq * 10 + Skv)
        q = jnp.asarray(rng.normal(size=(B, Sq, nq, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Skv, nkv, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Skv, nkv, hd)), jnp.float32)
        q_pos = jnp.arange(Skv - Sq, Skv)[None].repeat(B, 0)
        kv_pos = jnp.arange(Skv)[None].repeat(B, 0)
        out = flash_attention(q, k, v, q_pos, kv_pos, window=win,
                              block_q=16, block_k=16)
        ref = flash_attention_ref(q, k, v, q_pos, kv_pos, window=win)
        assert float(jnp.abs(out - ref).max()) < 5e-6
else:
    def test_flash_attention_property():
        pytest.skip("hypothesis not installed; property sweep skipped")

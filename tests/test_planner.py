"""Batched XLA planner (core/planner.py): numpy-reference equivalence on
every registered scenario + legacy, vmapped-batched == single-plan
bitwise consistency, end-to-end runner parity, and the bench smoke.

Documented tolerances (DESIGN.md §"Batched XLA planner"): alpha is
bitwise-equal (SUBP1 is shared); l/phi/t_bar agree within the BCD
fixed-point tolerance bcd_eps=1e-3 (measured drift is ~bw_tol=1e-5, from
convergence checks straddling an iteration boundary); b_gen within 1.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.planner import bucket_size, plan_selected_jax, \
    selected_consts
from repro.core.two_scale import plan_round, plan_rounds_batched
from repro.sim import SCENARIOS, VehicularWorld, get_scenario

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
MODEL_BITS = 11.2e6 * 32

L_ATOL = 1e-3        # == bcd_eps: one outer fixed-point step
PHI_ATOL = 1e-3
TBAR_ATOL = 1e-3


def _legacy_fleets(rng, cfg, n=40, rounds=3):
    hists = rng.dirichlet(np.full(10, 0.3), size=n)
    sizes = rng.integers(500, 2000, size=n)
    return [mobility.sample_fleet(rng, cfg, hists, sizes)
            for _ in range(rounds)]


def _world_fleets(name, rng, cfg, n=40, rounds=3):
    hists = rng.dirichlet(np.full(10, 0.3), size=n)
    sizes = rng.integers(500, 2000, size=n)
    world = VehicularWorld(cfg, get_scenario(name), n_partitions=n, rng=rng)
    fleets = []
    for _ in range(rounds):
        fleets.append(world.fleet(hists, sizes)[0])
        world.step(rng, 2.0)
    return fleets


@pytest.mark.parametrize("scenario", sorted(SCENARIOS) + ["legacy"])
def test_planner_equivalence(scenario):
    """Seeded 3-round plan chains (b_prev threaded like the runner does):
    the jitted planner must match the numpy reference on every scenario."""
    rng = np.random.default_rng(7)
    if scenario == "legacy":
        cfg = GenFVConfig()
        fleets = _legacy_fleets(rng, cfg)
    else:
        cfg = get_scenario(scenario).apply(GenFVConfig())
        fleets = _world_fleets(scenario, rng, cfg)
    b_prev = 0
    planned = 0
    for fleet in fleets:
        pn = plan_round(cfg, fleet, MODEL_BITS, batches=8, b_prev=b_prev,
                        planner="numpy")
        pj = plan_round(cfg, fleet, MODEL_BITS, batches=8, b_prev=b_prev,
                        planner="jax")
        np.testing.assert_array_equal(pn.alpha, pj.alpha)   # SUBP1 bitwise
        assert pn.selected == pj.selected
        if not pn.selected:
            continue
        planned += 1
        np.testing.assert_allclose(pj.l, pn.l, atol=L_ATOL)
        np.testing.assert_allclose(pj.phi, pn.phi, atol=PHI_ATOL)
        np.testing.assert_allclose(pj.t_mu, pn.t_mu, atol=TBAR_ATOL)
        assert pj.t_bar == pytest.approx(pn.t_bar, abs=TBAR_ATOL)
        assert abs(pj.b_gen - pn.b_gen) <= 1
        np.testing.assert_array_equal(pn.t_cp, pj.t_cp)     # shared consts
        assert len(pj.history) == pj.bcd_iters
        b_prev = pn.b_gen
    assert planned >= 1          # the draw must exercise the BCD


def test_batched_matches_single_bitwise():
    """plan_rounds_batched == per-fleet plan_round(planner="jax") exactly:
    the done-guarded while loops freeze converged lanes, so extra vmap
    iterations are no-ops even across different selected-set sizes."""
    cfg = GenFVConfig()
    fleets = []
    for s in (0, 1, 2):
        rng = np.random.default_rng(200 + s)
        hists = rng.dirichlet(np.full(10, 0.4), size=12 * (s + 1))
        sizes = rng.integers(500, 2000, size=12 * (s + 1))
        fleets.append(mobility.sample_fleet(rng, cfg, hists, sizes))
    batched = plan_rounds_batched(cfg, fleets, MODEL_BITS, batches=8,
                                  b_prevs=[0, 5, 64])
    ks = {len(p.selected) for p in batched}
    assert len(ks) > 1           # the point: heterogeneous K in one dispatch
    for fleet, b_prev, bp in zip(fleets, [0, 5, 64], batched):
        single = plan_round(cfg, fleet, MODEL_BITS, batches=8,
                            b_prev=b_prev, planner="jax")
        np.testing.assert_array_equal(single.alpha, bp.alpha)
        np.testing.assert_array_equal(single.l, bp.l)
        np.testing.assert_array_equal(single.phi, bp.phi)
        np.testing.assert_array_equal(single.t_mu, bp.t_mu)
        assert single.t_bar == bp.t_bar
        assert single.b_gen == bp.b_gen
        assert single.t_rsu == bp.t_rsu
        assert single.bcd_iters == bp.bcd_iters
        assert single.history == bp.history


def test_bucket_padding_invariant():
    """Padding the same selected set into a LARGER bucket must not change
    the plan at all: padded slots carry zero subcarriers / False masks."""
    cfg = GenFVConfig()
    rng = np.random.default_rng(11)
    hists = rng.dirichlet(np.full(10, 0.4), size=20)
    sizes = rng.integers(500, 2000, size=20)
    fleet = mobility.sample_fleet(rng, cfg, hists, sizes)
    plan = plan_round(cfg, fleet, MODEL_BITS, batches=8, planner="jax")
    k = len(plan.selected)
    if k == 0:
        pytest.skip("no vehicles selected in this draw")
    from repro.core.generation import DiffusionService
    consts = selected_consts(cfg, fleet, plan.selected, 8)
    svc = DiffusionService(steps=cfg.diffusion_steps)
    base = plan_selected_jax(cfg, MODEL_BITS, consts, 0, svc,
                             cfg.bcd_eps, cfg.bcd_max_iter)
    bigger = plan_selected_jax(cfg, MODEL_BITS, consts, 0, svc,
                               cfg.bcd_eps, cfg.bcd_max_iter,
                               bucket=4 * bucket_size(k))
    for key in ("l", "phi", "t_mu", "e_mu"):
        np.testing.assert_array_equal(bigger[key], base[key], err_msg=key)
    for key in ("t_bar", "b_gen", "t_rsu", "bcd_iters", "history"):
        assert bigger[key] == base[key], key


def test_empty_selection_both_backends():
    cfg = GenFVConfig()
    rng = np.random.default_rng(0)
    hists = rng.dirichlet(np.full(10, 0.4), size=6)
    sizes = rng.integers(500, 2000, size=6)
    fleet = mobility.sample_fleet(rng, cfg, hists, sizes)
    override = np.zeros(len(fleet), np.int32)
    for planner in ("numpy", "jax"):
        plan = plan_round(cfg, fleet, MODEL_BITS, batches=8,
                          alpha_override=override, planner=planner)
        assert plan.selected == [] and plan.b_gen == 0
        assert plan.l.shape == (0,) and plan.t_bar == 0.0
    with pytest.raises(ValueError, match="unknown planner"):
        plan_round(cfg, fleet, MODEL_BITS, batches=8, planner="torch")


def test_runner_end_to_end_planner_parity():
    """Seeded rush_hour runs: the jax-planner curves must match the
    numpy-planner run within noise (acceptance bar). Integer decisions
    (selection counts, generation schedule) must agree exactly; accuracy
    may drift only through sub-tolerance t_bar differences feeding the
    world clock."""
    from repro.fl.rounds import GenFVRunner, RunConfig
    curves = {}
    for planner in ("numpy", "jax"):
        run = RunConfig(rounds=3, train_size=300, test_size=32,
                        width_mult=0.0625, strategy="genfv", seed=0,
                        scenario="rush_hour", planner=planner)
        cfg = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)
        curves[planner] = GenFVRunner(run, fl_cfg=cfg).train()
    for key in ("selected", "b_gen", "dropped"):
        np.testing.assert_array_equal(curves["numpy"].curve(key),
                                      curves["jax"].curve(key), err_msg=key)
    np.testing.assert_allclose(curves["jax"].curve("t_bar"),
                               curves["numpy"].curve("t_bar"),
                               atol=TBAR_ATOL)
    np.testing.assert_allclose(curves["jax"].curve("accuracy"),
                               curves["numpy"].curve("accuracy"), atol=0.1)


# ---------------------------------------------------------------------------
# Bench smoke (tier-1 wiring, mirroring bench_world --quick)
# ---------------------------------------------------------------------------
def test_bench_planner_quick_smoke(tmp_path):
    out = tmp_path / "BENCH_planner.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_planner", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    import json
    res = json.loads(out.read_text())
    assert res["quick"] is True
    assert res["single"]["jax_ms"] > 0
    assert res["batched"][0]["speedup"] > 0

"""Fleet engine (fl/fleet.py): equivalence with the sequential reference
path, bucket/padding invariants, and the bench smoke run.

The sequential reference is the seed implementation: per-vehicle jitted
`client_update` + host-side `core/emd.py::aggregate`. The engine must match
it to tight numerical tolerance (vmap may schedule convs differently, so
bitwise equality is only guaranteed *across bucket sizes*, not across
engines).
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.configs.genfv_cifar import cnn_config
from repro.core.emd import aggregate, data_weights, mean_emd
from repro.data.synthetic import make_image_dataset
from repro.fl.client import client_update
from repro.fl.fleet import FleetEngine, bucket_size
from repro.fl.rounds import GenFVRunner, RunConfig
from repro.models.cnn import init_cnn

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
CFG = cnn_config("cifar10", 0.0625)
K, H, B = 3, 2, 4
EMDS = [0.4, 0.6, 0.5]


@pytest.fixture(scope="module")
def setup():
    params = init_cnn(jax.random.PRNGKey(0), CFG)
    aug = init_cnn(jax.random.PRNGKey(1), CFG)
    imgs, labels = make_image_dataset("cifar10", 240, seed=0)
    imgs = imgs[:, ::2, ::2, :]          # 16x16: keep tier-1 fast
    datasets = [(imgs[i::K], labels[i::K]) for i in range(K)]
    sizes = [len(d[1]) for d in datasets]
    return params, aug, datasets, sizes


def _engine_batches(engine, datasets, seed=0):
    rng = np.random.default_rng(seed)
    bi, bl = zip(*[engine.sample_batches(rng, di, dl) for di, dl in datasets])
    return list(bi), list(bl)


def _leaves_allclose(a, b, tol=2e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=tol, rtol=tol)


def _leaves_equal(a, b):
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_bucket_size():
    assert [bucket_size(k) for k in (1, 2, 3, 4, 5, 16, 17)] == \
        [4, 4, 4, 4, 8, 16, 32]          # floor 4: see fl/fleet.py
    assert bucket_size(2, min_bucket=1) == 2
    with pytest.raises(ValueError):
        bucket_size(10, max_bucket=8)


@pytest.mark.parametrize("prox_mu", [0.0, 0.5])
def test_engine_matches_sequential(setup, prox_mu):
    """Vmapped fleet + fused aggregation == per-vehicle client_update + host
    aggregate, including the FedProx branch, with K=3 padded into bucket 4
    (so padded-slot masking is exercised too)."""
    params, aug, datasets, sizes = setup
    rng = np.random.default_rng(0)
    models, seq_losses = [], []
    for di, dl in datasets:
        m, l = client_update(params, CFG, di, dl, rng, H, B, 5e-2,
                             prox_mu=prox_mu)
        models.append(m)
        seq_losses.append(l)
    ref = aggregate(models, data_weights(sizes), aug, mean_emd(EMDS))

    engine = FleetEngine(CFG, H, B, 5e-2, donate=False)
    bi, bl = _engine_batches(engine, datasets)   # same rng protocol -> same batches
    new, losses = engine.run(params, bi, bl, data_weights(sizes),
                             mean_emd(EMDS), aug, prox_mu=prox_mu)
    _leaves_allclose(ref, new)
    np.testing.assert_allclose(losses, seq_losses, atol=1e-5, rtol=1e-5)


def test_engine_no_aug_is_weighted_fedavg(setup):
    """aug_params=None must reduce to kappa2=0 weighted FedAvg (the FL-only
    baseline), matching the host path with a zero-EMD aggregate."""
    params, _, datasets, sizes = setup
    rng = np.random.default_rng(0)
    models = [client_update(params, CFG, di, dl, rng, H, B, 5e-2)[0]
              for di, dl in datasets]
    ref = aggregate(models, data_weights(sizes), models[0], 0.0)

    engine = FleetEngine(CFG, H, B, 5e-2, donate=False)
    bi, bl = _engine_batches(engine, datasets)
    new, _ = engine.run(params, bi, bl, data_weights(sizes), aug_params=None)
    _leaves_allclose(ref, new)


def test_bucket_padding_bitwise_stable(setup):
    """K=3 vehicles run in bucket 4, 8, and 16 must produce bitwise-identical
    aggregates and losses: masked padding must not change the result."""
    params, aug, datasets, sizes = setup
    engine = FleetEngine(CFG, H, B, 5e-2, donate=False)
    bi, bl = _engine_batches(engine, datasets)
    outs, losses = {}, {}
    for bucket in (4, 8, 16):
        outs[bucket], losses[bucket] = engine.run(
            params, bi, bl, data_weights(sizes), mean_emd(EMDS), aug,
            prox_mu=0.5, bucket=bucket)
    for bucket in (8, 16):
        assert _leaves_equal(outs[4], outs[bucket]), \
            f"aggregate drifted between bucket 4 and {bucket}"
        np.testing.assert_array_equal(losses[4], losses[bucket])


def test_exact_bucket_vs_padded(setup):
    """A fleet that exactly fills its bucket (K=4 -> bucket 4, no padding)
    must match the same fleet padded into a larger bucket."""
    params, aug, datasets, sizes = setup
    engine = FleetEngine(CFG, H, B, 5e-2, donate=False)
    bi, bl = _engine_batches(engine, datasets)
    bi4, bl4 = bi + [bi[0]], bl + [bl[0]]    # 4th vehicle reuses data
    sizes4, emds4 = sizes + [sizes[0]], EMDS + [EMDS[0]]
    exact, _ = engine.run(params, bi4, bl4, data_weights(sizes4),
                          mean_emd(emds4), aug, bucket=4)
    padded, _ = engine.run(params, bi4, bl4, data_weights(sizes4),
                           mean_emd(emds4), aug, bucket=16)
    assert _leaves_equal(exact, padded)


def test_engine_rejects_bad_args(setup):
    params, _, datasets, sizes = setup
    engine = FleetEngine(CFG, H, B, 5e-2, donate=False)
    with pytest.raises(ValueError):
        engine.run(params, [], [], [])
    bi, bl = _engine_batches(engine, datasets)
    with pytest.raises(ValueError):
        engine.run(params, bi, bl, data_weights(sizes), bucket=2)  # 2 < K=3


def test_runner_vectorized_matches_sequential():
    """End-to-end GenFVRunner: the vectorized engine path and the sequential
    reference path consume the same rng stream, so per-round losses agree to
    vmap tolerance and accuracy matches."""
    fast = dict(rounds=1, train_size=400, test_size=32, width_mult=0.125,
                strategy="fedavg")
    fl_cfg = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)
    curves = {}
    for vec in (True, False):
        r = GenFVRunner(RunConfig(vectorized=vec, **fast), fl_cfg=fl_cfg)
        res = r.train()
        curves[vec] = res
    np.testing.assert_allclose(curves[True].curve("loss"),
                               curves[False].curve("loss"), atol=1e-4)
    np.testing.assert_array_equal(curves[True].curve("accuracy"),
                                  curves[False].curve("accuracy"))
    np.testing.assert_array_equal(curves[True].curve("selected"),
                                  curves[False].curve("selected"))


def test_bench_rounds_quick_smoke(tmp_path):
    """The perf bench must stay runnable (--quick) so engine regressions
    fail fast; asserts the JSON artifact shape, not the speedup (CI noise)."""
    out = tmp_path / "BENCH_rounds.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_rounds", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["quick"] is True
    assert [row["K"] for row in data["results"]] == [4, 8]
    assert [row["bucket"] for row in data["results"]] == [4, 8]
    for row in data["results"]:
        assert row["rounds_per_sec_vectorized"] > 0
        assert row["rounds_per_sec_sequential"] > 0
        assert row["speedup"] > 0

"""Fault tolerance (fl/faults.py + the rounds.py recovery path): registry
and spec validation, round-keyed injection determinism, the no-injection
bitwise equivalence, staleness-weighted straggler recovery, the in-kernel
poison guard, golden checkpoint resume on both planner backends, and the
resumable sweep."""
import dataclasses
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GenFVConfig
from repro.core.emd import aggregate_stacked, aggregate_stacked_guarded, \
    tree_finite
from repro.exp import ExperimentSpec, Sweep
from repro.fl.faults import (FaultInjector, FaultSpec, RoundFaults,
                             StaleBuffer, StaleEntry, fault_names, get_fault,
                             realized_arrivals, realized_times,
                             register_fault)
from repro.fl.rounds import GenFVRunner, RunConfig

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")

FAST = dict(rounds=3, train_size=300, test_size=32, width_mult=0.0625)
FAST_CFG = GenFVConfig(batch_size=8, local_steps=2, num_vehicles=6)

#: RoundLog curves compared in the determinism / parity / resume tests
LOG_KEYS = ("selected", "dropped", "late", "rejected", "stale_merged",
            "t_bar", "t_round", "b_gen", "kappa2", "emd_bar", "loss",
            "accuracy")


def _curves(res):
    return {k: res.curve(k) for k in LOG_KEYS}


def _assert_same(res_a, res_b, keys=LOG_KEYS):
    ca, cb = _curves(res_a), _curves(res_b)
    for k in keys:
        np.testing.assert_array_equal(ca[k], cb[k], err_msg=k)


# ---------------------------------------------------------------------------
# Registry + spec validation
# ---------------------------------------------------------------------------
def test_registry_presets():
    names = fault_names()
    for required in ("platoon_mass_dropout", "rush_hour_deep_fade",
                     "compute_stragglers", "poison_minority", "mixed_stress"):
        assert required in names
    with pytest.raises(KeyError, match="unknown fault schedule"):
        get_fault("solar_flare")
    with pytest.raises(ValueError, match="already registered"):
        register_fault("mixed_stress", FaultSpec())


@pytest.mark.parametrize("kw,fragment", [
    (dict(straggler_prob=1.5), "outside"),
    (dict(outage_prob=-0.1), "outside"),
    (dict(straggler_slowdown=0.5), "slowdown"),
    (dict(deadline_slack=-1.0), "deadline_slack"),
    (dict(staleness_discount=0.0), "staleness_discount"),
    (dict(max_staleness=-1), "max_staleness"),
])
def test_spec_validation(kw, fragment):
    with pytest.raises(ValueError, match=fragment):
        FaultSpec(**kw)


def test_spec_active_window_and_payload():
    spec = FaultSpec(seed=9, start_round=2, end_round=5, outage_prob=0.3)
    assert [spec.active(t) for t in range(6)] == \
        [False, False, True, True, True, False]
    assert FaultSpec.from_payload(spec.to_payload()) == spec


def test_runconfig_faults_field():
    RunConfig(faults="mixed_stress", **FAST)       # registered name: valid
    RunConfig(faults=None, **FAST)                 # fault-free: valid
    with pytest.raises(ValueError, match="unknown fault schedule"):
        RunConfig(faults="solar_flare", **FAST)


# ---------------------------------------------------------------------------
# Injector: pure function of (spec.seed, round, fleet size)
# ---------------------------------------------------------------------------
def test_injector_round_keyed_determinism():
    spec = FaultSpec(seed=7, straggler_prob=0.5, outage_prob=0.5,
                     departure_prob=0.5, poison_prob=0.5)
    inj = FaultInjector(spec)
    a, b = inj.draw(3, 8), inj.draw(3, 8)
    for f in ("slowdown", "outage", "departed", "poisoned"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    # different rounds draw from different streams
    c = inj.draw(4, 8)
    assert any(not np.array_equal(getattr(a, f), getattr(c, f))
               for f in ("slowdown", "outage", "departed", "poisoned"))
    # a departed vehicle's update never arrives: poisoning it is moot
    assert not (a.departed & a.poisoned).any()


def test_injector_benign_cases():
    inj = FaultInjector(FaultSpec(seed=1, start_round=5, departure_prob=1.0))
    assert inj.draw(0, 6).any is False             # inactive round
    assert inj.draw(5, 0).slowdown.shape == (0,)   # empty fleet
    assert inj.draw(5, 6).departed.all()           # active round


def test_realization_edge_cases():
    """k=0 and inactive-round paths through both realization functions."""
    from types import SimpleNamespace
    spec = FaultSpec(seed=2, start_round=5, outage_prob=1.0)
    inj = FaultInjector(spec)
    # k=0: every array is empty, no stream is touched
    rf0 = inj.draw(7, 0)
    plan0 = SimpleNamespace(selected=[], t_cp=np.zeros(0), t_mu=np.zeros(0),
                            l=np.zeros(0), phi=np.zeros(0))
    t0 = realized_times(FAST_CFG, [], plan0, 1e6, rf0, spec.outage_fade_db)
    a0, r0, x0 = realized_arrivals(FAST_CFG, [], plan0, 1e6, rf0, spec, 7,
                                   retry_budget=2, backoff_s=0.1,
                                   backoff_cap_s=1.0)
    assert t0.shape == a0.shape == r0.shape == x0.shape == (0,)
    # inactive round: benign draw => arrivals are exactly the nominal
    # t_cp + t_mu, no retries, nobody exhausted
    rf = inj.draw(0, 3)          # before start_round
    assert rf.any is False
    plan = SimpleNamespace(selected=[0, 1, 2],
                           t_cp=np.array([1.0, 2.0, 3.0]),
                           t_mu=np.array([0.5, 0.5, 0.5]),
                           l=np.ones(3), phi=np.ones(3))
    times, retries, exhausted = realized_arrivals(
        FAST_CFG, [], plan, 1e6, rf, spec, 0, retry_budget=2,
        backoff_s=0.1, backoff_cap_s=1.0)
    np.testing.assert_array_equal(times, np.array([1.5, 2.5, 3.5]))
    assert not retries.any() and not exhausted.any()
    np.testing.assert_array_equal(
        realized_times(FAST_CFG, [], plan, 1e6, rf, spec.outage_fade_db),
        times)


def test_outage_departed_overlap_never_retries():
    """A departed vehicle's retry must never be scheduled — its update can
    never arrive, whatever the outage realization says."""
    run = RunConfig(seed=3, **FAST)
    r = GenFVRunner(run, FAST_CFG)
    p = r.begin_round(0)
    plan = r.plan(p)
    k = len(plan.selected)
    assert k >= 2
    spec = FaultSpec(seed=1, outage_prob=1.0)   # no retry ever recovers
    dep = np.zeros(k, bool)
    dep[0] = True
    rf = RoundFaults(np.ones(k), np.ones(k, bool), dep, np.zeros(k, bool))
    times, retries, exhausted = realized_arrivals(
        r.cfg, p.fleet, plan, r.model_bits, rf, spec, 0,
        retry_budget=3, backoff_s=0.1, backoff_cap_s=0.5)
    # departed ∧ outage: no retry scheduled, not "exhausted" — just gone
    assert np.isinf(times[0]) and retries[0] == 0 and not exhausted[0]
    # pure outage at outage_prob=1: burns the whole budget, then exhausts
    assert np.isinf(times[1:]).all()
    assert (retries[1:] == 3).all() and exhausted[1:].all()
    # with recovery certain (outage_prob=0 means every retry draw clears),
    # one backoff + the nominally-priced upload lands a finite arrival
    spec_ok = FaultSpec(seed=1, outage_prob=0.0)
    rf1 = RoundFaults(np.ones(k), np.eye(1, k, 1, dtype=bool)[0],
                      np.zeros(k, bool), np.zeros(k, bool))
    t1, r1, x1 = realized_arrivals(
        r.cfg, p.fleet, plan, r.model_bits, rf1, spec_ok, 0,
        retry_budget=3, backoff_s=0.1, backoff_cap_s=0.5)
    nominal = np.asarray(plan.t_cp) + np.asarray(plan.t_mu)
    assert np.isfinite(t1[1]) and t1[1] > nominal[1] and r1[1] == 1
    assert not x1.any()


def test_stale_dropped_reaches_round_ledger():
    """Updates aged past max_staleness surface in RoundLog.stale_dropped
    instead of vanishing silently."""
    spec = FaultSpec(seed=11, straggler_prob=1.0, straggler_slowdown=50.0,
                     deadline_slack=0.0, max_staleness=0)
    run = RunConfig(seed=0, **FAST)
    res = GenFVRunner(run, FAST_CFG, faults=spec).train()
    late = sum(l.late for l in res.logs)
    dropped = sum(l.stale_dropped for l in res.logs)
    merged = sum(l.stale_merged for l in res.logs)
    assert late > 0
    # max_staleness=0: nothing buffered at round t survives to t+1
    assert merged == 0 and dropped > 0


def test_stale_buffer_ages_and_drop():
    buf = StaleBuffer()
    for t in (0, 1, 3):
        buf.push(StaleEntry(params=None, size=10, emd=0.5, trained_round=t,
                            vid=t))
    assert len(buf) == 3
    merge, ages, dropped = buf.pop_mergeable(3, max_staleness=2)
    # trained at 0 is age 3 > 2: too stale, dropped AND counted
    assert [e.trained_round for e in merge] == [1, 3] and ages == [2, 0]
    assert dropped == 1
    assert len(buf) == 0                           # drained either way


def test_stale_buffer_boundary_age_merges():
    # age == max_staleness is inclusive: the entry still merges (dropping
    # starts strictly beyond the bound) and the drop counter stays zero
    buf = StaleBuffer()
    buf.push(StaleEntry(params=None, size=10, emd=0.5, trained_round=0,
                        vid=0))
    merge, ages, dropped = buf.pop_mergeable(2, max_staleness=2)
    assert len(merge) == 1 and ages == [2] and dropped == 0
    # one past the bound: dropped, counted, nothing mergeable
    buf.push(StaleEntry(params=None, size=10, emd=0.5, trained_round=0,
                        vid=1))
    merge, ages, dropped = buf.pop_mergeable(3, max_staleness=2)
    assert merge == [] and ages == [] and dropped == 1


# ---------------------------------------------------------------------------
# Guarded aggregation kernel
# ---------------------------------------------------------------------------
def test_guarded_kernel_neutral_on_finite():
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)}
    aug = {"w": jnp.zeros(4), "b": jnp.ones(2)}
    fb = {"w": jnp.full(4, 9.0), "b": jnp.full(2, 9.0)}
    w = jnp.asarray([0.2, 0.3, 0.5], jnp.float32)
    plain = aggregate_stacked(stacked, w, aug, jnp.float32(0.25))
    guarded, finite = aggregate_stacked_guarded(stacked, w, aug,
                                                jnp.float32(0.25), fb)
    assert finite.all()
    for k in stacked:
        np.testing.assert_array_equal(np.asarray(plain[k]),
                                      np.asarray(guarded[k]), err_msg=k)


def test_guarded_kernel_rejects_and_renormalizes():
    stacked = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [np.nan, 5.0]],
                                jnp.float32)}
    aug = {"w": jnp.zeros(2, jnp.float32)}
    fb = {"w": jnp.full(2, 7.0, jnp.float32)}
    w = jnp.asarray([0.25, 0.25, 0.5], jnp.float32)
    out, finite = aggregate_stacked_guarded(stacked, w, aug,
                                            jnp.float32(0.0), fb)
    np.testing.assert_array_equal(np.asarray(finite), [True, True, False])
    # survivors absorb the poisoned client's mass: (0.25*r0+0.25*r1) * (1/0.5)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0], rtol=1e-6)
    # all-poisoned: the federated mass redirects to the fallback
    poisoned = {"w": jnp.full((3, 2), jnp.nan, jnp.float32)}
    out2, finite2 = aggregate_stacked_guarded(poisoned, w, aug,
                                              jnp.float32(0.0), fb)
    assert not np.asarray(finite2).any()
    np.testing.assert_allclose(np.asarray(out2["w"]), [7.0, 7.0], rtol=1e-6)


# ---------------------------------------------------------------------------
# No-injection equivalence: the fault plumbing must cost NOTHING when benign
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("vectorized", [True, False])
def test_no_injection_bitwise_equivalence(vectorized):
    """faults=None and an all-zero-probability FaultSpec must produce
    bitwise-identical RoundLogs: clean rounds keep dispatching the seed's
    unguarded kernel (the guarded one is a different fused XLA program)."""
    run = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                    vectorized=vectorized, **FAST)
    plain = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    benign = GenFVRunner(run, fl_cfg=FAST_CFG,
                         faults=FaultSpec(seed=1)).train()
    _assert_same(plain, benign)


def test_fault_run_deterministic():
    """Determinism guard (round-keyed injection): two fresh runners under the
    same registered schedule produce identical RoundLog curves."""
    run = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                    faults="mixed_stress", **FAST)
    a = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    b = GenFVRunner(run, fl_cfg=FAST_CFG).train()
    _assert_same(a, b)


# ---------------------------------------------------------------------------
# Degradation + recovery behavior
# ---------------------------------------------------------------------------
def test_straggler_recovery_ledger():
    """Everyone straggles past the deadline: updates are buffered, then
    merged next round with staleness discount — and none are lost except the
    final round's (nothing left to merge them into)."""
    spec = FaultSpec(seed=3, straggler_prob=1.0, straggler_slowdown=50.0,
                     deadline_slack=0.05)
    run = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                    rounds=4, train_size=300, test_size=32,
                    width_mult=0.0625)
    res = GenFVRunner(run, fl_cfg=FAST_CFG, faults=spec).train()
    late = res.curve("late")
    merged = res.curve("stale_merged")
    assert late.sum() > 0
    # conservation: every buffered update is merged exactly one round later
    np.testing.assert_array_equal(merged[1:], late[:-1])
    assert merged[0] == 0
    # a late round holds the RSU open until the deadline (> planned t_bar)
    for log in res.logs:
        if log.late:
            assert log.t_round == pytest.approx(
                log.t_bar * (1 + spec.deadline_slack))
            assert log.t_round > log.t_bar
        assert np.isfinite(log.loss) and 0.0 <= log.accuracy <= 1.0


@pytest.mark.parametrize("vectorized", [True, False])
def test_all_poisoned_round_falls_back(vectorized):
    """poison_prob=1: the guard rejects every update, the global degrades to
    'no federated progress' (never NaN/zero-collapse), and the ledger counts
    every participant as rejected."""
    spec = FaultSpec(seed=4, poison_prob=1.0)
    run = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                    vectorized=vectorized, **FAST)
    r = GenFVRunner(run, fl_cfg=FAST_CFG, faults=spec)
    res = r.train()
    for log in res.logs:
        assert log.rejected == log.selected - log.late
        assert 0.0 <= log.accuracy <= 1.0
    assert res.curve("rejected").sum() > 0
    assert tree_finite(r.server.params)            # model never corrupted


def test_poison_minority_vec_seq_parity():
    """Partial poisoning: the in-kernel guard (vectorized) and the host-side
    guard (sequential reference) must agree on the full ledger AND the
    model trajectory — the renormalized survivor weights are identical."""
    run_v = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                      faults="poison_minority", vectorized=True, **FAST)
    run_s = dataclasses.replace(run_v, vectorized=False)
    a = GenFVRunner(run_v, fl_cfg=FAST_CFG).train()
    b = GenFVRunner(run_s, fl_cfg=FAST_CFG).train()
    assert a.curve("rejected").sum() > 0           # the schedule actually bit
    _assert_same(a, b, keys=("selected", "dropped", "late", "rejected",
                             "stale_merged", "accuracy"))


# ---------------------------------------------------------------------------
# Golden resume: checkpoint mid-run, reload into a fresh runner, finish —
# bitwise-equal to the uninterrupted run, on both planner backends, with
# and without an active fault schedule (the stale buffer crosses the
# checkpoint boundary under mixed_stress).
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("planner", ["jax", "numpy"])
@pytest.mark.parametrize("faults", [None, "mixed_stress"])
def test_golden_resume(planner, faults, tmp_path):
    run = RunConfig(strategy="genfv", scenario="rush_hour", seed=0,
                    planner=planner, faults=faults, **FAST)
    full = GenFVRunner(run, fl_cfg=FAST_CFG).train()

    path = str(tmp_path / "runner.npz")
    interrupted = GenFVRunner(run, fl_cfg=FAST_CFG)
    for t in range(2):
        interrupted.run_round(t)
    interrupted.save_checkpoint(path)

    resumed = GenFVRunner(run, fl_cfg=FAST_CFG)
    assert resumed.load_checkpoint(path) == 2
    res = resumed.train()
    assert len(res.logs) == FAST["rounds"]
    for full_log, res_log in zip(full.logs, res.logs):
        assert full_log == res_log                 # every field, bitwise


def test_checkpoint_atomic_on_partial_write(tmp_path, monkeypatch):
    """A crash mid-save (simulated: np.savez dies after writing partial
    bytes) must leave the previous checkpoint intact and no temp litter —
    the tmp-file + os.replace protocol's whole point."""
    import repro.checkpoint.io as ckpt_io
    from repro.checkpoint import read_manifest, restore_tree, save_tree
    path = str(tmp_path / "ckpt.npz")
    final = save_tree(path, {"a": np.arange(4.0)}, metadata={"step": 1})

    def torn_savez(f, **arrays):
        f.write(b"PK\x03\x04 half a zip")
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_io.np, "savez", torn_savez)
    with pytest.raises(OSError, match="disk full"):
        save_tree(path, {"a": np.zeros(4)}, metadata={"step": 2})
    monkeypatch.undo()
    # the old checkpoint is still the one on disk, fully readable
    assert read_manifest(final)["metadata"] == {"step": 1}
    np.testing.assert_array_equal(restore_tree(final)["a"], np.arange(4.0))
    assert [p.name for p in tmp_path.iterdir()] == ["ckpt.npz"]


def test_checkpoint_rejects_foreign_runconfig(tmp_path):
    path = str(tmp_path / "runner.npz")
    r = GenFVRunner(RunConfig(strategy="genfv", scenario="rush_hour",
                              seed=0, **FAST), fl_cfg=FAST_CFG)
    r.run_round(0)
    r.save_checkpoint(path)
    other = GenFVRunner(RunConfig(strategy="fedavg", scenario="rush_hour",
                                  seed=0, **FAST), fl_cfg=FAST_CFG)
    with pytest.raises(ValueError, match="different RunConfig"):
        other.load_checkpoint(path)


# ---------------------------------------------------------------------------
# Resumable sweep: kill mid-grid, resume, finish — metrics bitwise.
# ---------------------------------------------------------------------------
def _sweep_spec():
    return ExperimentSpec(
        name="faults-resume",
        strategies=("genfv",),
        base=RunConfig(**FAST),
        overrides=({}, {"faults": "mixed_stress"}))


def test_sweep_resume_mid_grid(tmp_path):
    spec = _sweep_spec()
    full = Sweep(spec, fl_cfg=FAST_CFG).run()
    d = str(tmp_path / "ckpt")
    part = Sweep(spec, fl_cfg=FAST_CFG).run(checkpoint_dir=d, stop_after=2)
    assert int(part.rounds.max()) == 2             # the simulated kill
    res = Sweep(spec, fl_cfg=FAST_CFG).run(checkpoint_dir=d)
    np.testing.assert_array_equal(res.rounds, full.rounds)
    for k in full.metrics:
        np.testing.assert_array_equal(res.metrics[k], full.metrics[k],
                                      err_msg=k)


def test_sweep_resume_guards(tmp_path):
    spec = _sweep_spec()
    d = str(tmp_path / "ckpt")
    Sweep(spec, fl_cfg=FAST_CFG).run(checkpoint_dir=d, stop_after=1)
    # a different spec must refuse the directory
    other = ExperimentSpec(name="other", strategies=("fedavg",),
                           base=RunConfig(**FAST))
    with pytest.raises(ValueError, match="different ExperimentSpec"):
        Sweep(other, fl_cfg=FAST_CFG).run(checkpoint_dir=d)
    # torn checkpoint: manifest claims more rounds than the cells hold
    man_path = os.path.join(d, "manifest.json")
    man = json.load(open(man_path))
    man["completed_rounds"] += 1
    json.dump(man, open(man_path, "w"))
    with pytest.raises(ValueError, match="torn checkpoint"):
        Sweep(spec, fl_cfg=FAST_CFG).run(checkpoint_dir=d)


# ---------------------------------------------------------------------------
# Bench smoke (tier-1 wiring, mirroring bench_sweep --quick)
# ---------------------------------------------------------------------------
def test_bench_faults_quick_smoke(tmp_path):
    out = tmp_path / "BENCH_faults.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_faults", "--quick",
         "--out", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["quick"] is True
    assert data["deterministic"] is True
    names = [row["faults"] for row in data["pairs"]]
    assert "platoon_mass_dropout" in names and "rush_hour_deep_fade" in names
    for row in data["pairs"]:
        assert 0.0 <= row["acc_faulted"] <= 1.0
        assert row["delay_inflation"] >= 1.0 - 1e-9

"""Optimizers + schedules (incl. MiniCPM's WSD)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, constant_schedule, cosine_schedule, momentum,
                         sgd, wsd_schedule)
from repro.optim.optimizers import clip_by_global_norm, global_norm


@pytest.mark.parametrize("make", [
    lambda: sgd(constant_schedule(0.1)),
    lambda: momentum(constant_schedule(0.05)),
    lambda: adamw(constant_schedule(0.1)),
])
def test_descends_quadratic(make):
    opt = make()
    params = {"x": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_weight_decay():
    opt = adamw(constant_schedule(0.1), weight_decay=0.1)
    params = {"x": jnp.array([5.0])}
    state = opt.init(params)
    grads = {"x": jnp.array([0.0])}
    p1, _ = opt.update(grads, state, params)
    assert float(p1["x"][0]) < 5.0      # decay pulls toward zero


def test_wsd_phases():
    f = wsd_schedule(1.0, total_steps=100, warmup=10, decay_frac=0.2)
    assert float(f(0)) == 0.0
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(50)) == pytest.approx(1.0)          # stable plateau
    assert float(f(79)) == pytest.approx(1.0)
    assert float(f(99)) < 0.1                          # decayed
    # monotone during decay
    d = [float(f(s)) for s in range(80, 100)]
    assert all(a >= b for a, b in zip(d, d[1:]))


def test_cosine_schedule():
    f = cosine_schedule(1.0, 100, warmup=10, final_frac=0.1)
    assert float(f(10)) == pytest.approx(1.0, abs=1e-2)
    assert float(f(100)) == pytest.approx(0.1, abs=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0), "b": jnp.full((2,), -10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(500.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

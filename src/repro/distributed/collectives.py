"""GenFV aggregation as a collective (DESIGN.md §4).

The paper's eq. (4) — kappa1 * sum_n rho_n w_n + kappa2 * w_a — is a
*weighted all-reduce*: each mesh cohort holds its locally-updated model and
a scalar weight (rho_n * kappa1 for vehicle cohorts, kappa2 for the RSU's
augmented cohort); the global model is psum(w * model) / psum-normalizer
over the ('pod','data') axes. This maps the wireless aggregation 1:1 onto
TPU collectives and is exercised by tests/test_distributed.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                  # jax >= 0.6 exposes it at top level
    _shard_map = jax.shard_map
except AttributeError:                # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map


def genfv_weighted_allreduce(models, weights, mesh: Mesh, axes=("data",)):
    """models: pytree stacked on axis 0 with one entry per mesh cohort
    (leading dim == prod(axes sizes)); weights: [n_cohorts] (already
    normalized: sum(weights) == 1, e.g. [k1*rho_1, ..., k1*rho_N, k2]).

    Returns the aggregated model, computed with a weighted psum under
    shard_map — the distributed form of eq. (4).
    """
    n = jax.tree.leaves(models)[0].shape[0]
    sizes = [mesh.shape[a] for a in axes]
    assert n == int(np.prod(sizes)), (n, sizes)

    in_specs = (jax.tree.map(lambda _: P(axes), models),
                P(axes))
    out_specs = jax.tree.map(lambda _: P(), models)

    def agg(local_model, local_w):
        # local_model leaves: [1, ...]; local_w: [1]
        scaled = jax.tree.map(
            lambda m: (m[0].astype(jnp.float32) * local_w[0]), local_model)
        summed = jax.tree.map(
            lambda m: jax.lax.psum(m, axes), scaled)
        return summed

    fn = _shard_map(agg, mesh=mesh, in_specs=in_specs,
                    out_specs=out_specs)
    return fn(models, weights)


from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        params_shardings, shard_leaf)
from repro.distributed.collectives import genfv_weighted_allreduce

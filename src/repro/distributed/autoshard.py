"""Logical activation-sharding constraints.

GSPMD propagation alone can drop the batch sharding of intermediates (we
observed attention scores replicated over the data axis — 16 GiB/device).
Model code therefore annotates activations with *logical* axes ("batch",
"model") via `aconstrain`; the launcher activates a mapping to physical mesh
axes around lower()/compile(). Outside the context (CPU tests) every
annotation is a no-op, and any dimension the mesh axis does not divide is
left unsharded (never pad silently).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

_STATE = {"batch": None, "model": None, "sizes": {}}


@contextmanager
def activation_sharding(mesh, *, batch_axes: Optional[Tuple[str, ...]] = None,
                        model_axis: str = "model"):
    """Activate logical->physical axis mapping for traces inside the block."""
    names = list(mesh.shape.keys())
    if batch_axes is None:
        batch_axes = tuple(n for n in names if n in ("pod", "data")) or None
    old = dict(_STATE)
    _STATE.update(batch=tuple(batch_axes) if batch_axes else None,
                  model=model_axis if model_axis in names else None,
                  sizes=dict(mesh.shape))
    try:
        yield
    finally:
        _STATE.clear()
        _STATE.update(old)
        _STATE.setdefault("sizes", {})


def _size(ax) -> int:
    sizes = _STATE["sizes"]
    if isinstance(ax, tuple):
        s = 1
        for a in ax:
            s *= sizes.get(a, 1)
        return s
    return sizes.get(ax, 1)


def aconstrain(x, logical: Sequence[Optional[str]]):
    """logical: per-dim 'batch' | 'model' | None. Applies
    with_sharding_constraint where the axis divides the dim."""
    if (_STATE["batch"] is None and _STATE["model"] is None) or x.ndim != len(logical):
        return x
    spec = []
    for dim, l in enumerate(logical):
        ax = _STATE["batch"] if l == "batch" else (
            _STATE["model"] if l == "model" else None)
        if ax is not None:
            n = _size(ax)
            if n > 1 and x.shape[dim] % n == 0 and x.shape[dim] >= n:
                spec.append(ax)
                continue
        spec.append(None)
    if all(s is None for s in spec):
        return x
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def active() -> bool:
    return _STATE["batch"] is not None or _STATE["model"] is not None


def logical_size(name: str) -> int:
    """Physical size of a logical axis in the active context (1 if inactive)."""
    ax = _STATE["batch"] if name == "batch" else (
        _STATE["model"] if name == "model" else None)
    return _size(ax) if ax is not None else 1

"""Sharding rules for the production mesh (DESIGN.md §7).

Strategy: FSDP+TP hybrid, *divisibility-aware* — a dimension is only sharded
if the mesh axis divides it exactly (no silent padding):

* params: the largest dim divisible by |model| is tensor-sharded over
  'model' (heads / d_ff / experts / vocab end up here naturally); a second
  dim divisible by |fsdp| = |pod|x|data| is FSDP-sharded. Stacked-layer
  leading dims (scan groups) are never sharded.
* batch: global batch over ('pod','data'); decode long_500k (batch=1)
  replicates the token and shards the *cache* instead.
* caches: batch over ('pod','data') when divisible, then kv-heads over
  'model', falling back to head_dim, falling back to replication.

All rules return NamedSharding pytrees usable as in_shardings/out_shardings.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        s *= mesh.shape[n]
    return s


def shard_leaf(shape: Sequence[int], mesh: Mesh, *, model_axis="model",
               fsdp_axes=None, skip_leading: bool = False) -> P:
    """Pick a PartitionSpec for one parameter leaf."""
    fsdp_axes = fsdp_axes if fsdp_axes is not None else _default_fsdp(mesh)
    ndim = len(shape)
    spec = [None] * ndim
    start = 1 if (skip_leading and ndim >= 3) else 0
    dims = sorted(range(start, ndim), key=lambda i: -shape[i])

    m = _axis_size(mesh, model_axis)
    used = None
    for i in dims:
        if shape[i] % m == 0 and shape[i] >= m:
            spec[i] = model_axis
            used = i
            break
    f = _axis_size(mesh, fsdp_axes)
    for i in dims:
        if i != used and shape[i] % f == 0 and shape[i] >= f:
            spec[i] = fsdp_axes
            break
    return P(*spec)


def _default_fsdp(mesh: Mesh):
    names = list(mesh.shape.keys())
    fsdp = tuple(n for n in names if n in ("pod", "data"))
    return fsdp if fsdp else (names[0],)


def _batch_axes(mesh: Mesh):
    return _default_fsdp(mesh)


def params_shardings(params_shapes: Any, mesh: Mesh) -> Any:
    """params_shapes: pytree of ShapeDtypeStruct (jax.eval_shape of init).

    Expert weights (path contains 'moe', shape [..., E, d_in, d_out]) are
    EXPERT-PARALLEL: the expert dim is sharded over 'model' (the dispatch
    buffer is resharded to match — models/moe.py), with FSDP on d_in/d_out.
    Everything else follows the generic largest-divisible-dim rule."""
    m = mesh.shape.get("model", 1)
    fsdp = _default_fsdp(mesh)
    f = _axis_size(mesh, fsdp)

    def rule(path, leaf):
        keys = [getattr(p, "key", None) for p in path]
        is_expert = ("moe" in keys and len(leaf.shape) >= 3
                     and "router" not in keys)
        if is_expert:
            edim = len(leaf.shape) - 3
            spec = [None] * len(leaf.shape)
            if leaf.shape[edim] % m == 0 and leaf.shape[edim] >= m:
                spec[edim] = "model"
                # FSDP the largest remaining matmul dim
                for i in sorted(range(edim + 1, len(leaf.shape)),
                                key=lambda i_: -leaf.shape[i_]):
                    if leaf.shape[i] % f == 0 and leaf.shape[i] >= f:
                        spec[i] = fsdp
                        break
                return NamedSharding(mesh, P(*spec))
        skip = len(leaf.shape) >= 3
        return NamedSharding(mesh, shard_leaf(leaf.shape, mesh,
                                              skip_leading=skip))

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def batch_shardings(batch_shapes: Any, mesh: Mesh) -> Any:
    """Activations/inputs: dim 0 (batch) over ('pod','data') when divisible."""
    baxes = _batch_axes(mesh)
    b = _axis_size(mesh, baxes)

    def rule(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % b == 0 and leaf.shape[0] >= b:
            spec[0] = baxes
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(rule, batch_shapes)


def cache_shardings(cache_shapes: Any, mesh: Mesh) -> Any:
    """KV caches [B, cap, nkv, hd], positions [B, cap], recurrent states
    [B, w] / [B, h, hd, hd]: batch over ('pod','data'); one more dim over
    'model' when divisible (head_dim > kv-heads > width).

    Structure-aware: leaves under "groups" are stacked over the scan-group
    axis (leading dim G) which is never sharded (scan slices it)."""
    baxes = _batch_axes(mesh)
    b = _axis_size(mesh, baxes)
    m = mesh.shape.get("model", 1)

    def rule(offset):
        def f(leaf):
            shape = leaf.shape
            spec = [None] * len(shape)
            dims = list(range(offset, len(shape)))
            if dims and shape[dims[0]] % b == 0 and shape[dims[0]] >= b:
                spec[dims[0]] = baxes
            for i in reversed(dims[1:]):
                if i == dims[0] + 1 and len(dims) == 4:
                    continue   # never shard the ring-buffer seq dim of kv caches
                if shape[i] % m == 0 and shape[i] >= m:
                    spec[i] = "model"
                    break
            return NamedSharding(mesh, P(*spec))
        return f

    if isinstance(cache_shapes, dict) and ("groups" in cache_shapes
                                           or "rem" in cache_shapes):
        out = {}
        if "groups" in cache_shapes:
            out["groups"] = jax.tree.map(rule(1), cache_shapes["groups"])
        if "rem" in cache_shapes:
            out["rem"] = jax.tree.map(rule(0), cache_shapes["rem"])
        return out
    return jax.tree.map(rule(0), cache_shapes)


def replicated(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def describe(shardings: Any, max_items: int = 20) -> str:
    """Debug helper: path -> spec lines."""
    lines = []
    for path, s in jax.tree_util.tree_flatten_with_path(shardings)[0][:max_items]:
        lines.append(f"{jax.tree_util.keystr(path)}: {s.spec}")
    return "\n".join(lines)

"""Round-keyed AIGC generation service for the GenFV round loop.

`BatchedDDPMGenerator` is the `RunConfig(generator="ddpm")` implementation
of the server's generator interface: every round's full SUBP4 schedule —
all selected vehicles' per-label counts concatenated by `label_schedule` —
is sampled in ONE bucketed jitted dispatch (gen/sampler.py).

Determinism contract (mirrors fl/faults.py): the sampling stream of round
``t`` is keyed ``SeedSequence((seed, t, GEN_KEY))`` and the generator never
touches the runner's shared numpy Generator — so generation is a pure
function of (pretrained params, run seed, round, schedule), identical
across vectorized/sequential paths and across checkpoint resume. The
oracle keeps consuming the shared stream in the seed's order, which is what
keeps `generator="oracle"` runs bitwise-unchanged.
"""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from repro.core.planner import bucket_size
from repro.diffusion.ddpm import DDPM
from repro.gen.pretrain import pretrain_ddpm
from repro.gen.sampler import sample_schedule
from repro.obs import NULL_OBS

#: domain tag of the generation key stream ("AIGC"), keeping it disjoint
#: from every other (seed, round)-keyed stream in the repo (fl/faults.py
#: uses 0x52545259 "RTRY" for upload retries).
GEN_KEY = 0x41494743

#: the RSU "foundation model" served for `RunConfig(generator="ddpm")`:
#: the paper's 200-step noise schedule (Sec. VI-A2), a width the container
#: CPU can pretrain and sample in test time. `RunConfig.sampler_steps`
#: strides this schedule at sampling time.
RUNNER_TIMESTEPS = 200
RUNNER_BASE_WIDTH = 16
#: reference-pool pretraining budget (gen/pretrain.py); deliberately seeded
#: at 0 independent of the run seed — one pretrained generator stands in
#: for the RSU's foundation model across every cell of a sweep, while the
#: per-round sampling streams stay keyed by the run seed.
PRETRAIN_SEED = 0
PRETRAIN_STEPS = 80
PRETRAIN_REF = 512


def gen_round_key(seed: int, round_idx: int):
    """Raw PRNG key of round ``round_idx``'s sampling stream."""
    ss = np.random.SeedSequence(
        entropy=(int(seed), int(round_idx), GEN_KEY))
    return jnp.asarray(ss.generate_state(2, np.uint32))


def runner_ddpm(num_classes: int) -> DDPM:
    return DDPM(timesteps=RUNNER_TIMESTEPS, num_classes=num_classes,
                base_width=RUNNER_BASE_WIDTH)


@lru_cache(maxsize=4)
def _pretrained_params(dataset: str, num_classes: int, timesteps: int,
                       base_width: int, steps: int, ref_size: int,
                       seed: int):
    """One reference-pool pretraining per configuration per process;
    deterministic (fixed seed + keyed batch stream), so every runner —
    including a resumed one — reconstructs bitwise-identical params and
    the generator itself needs no checkpointing. The full budget is part
    of the cache key so a test-shrunk configuration never aliases the
    default one."""
    ddpm = DDPM(timesteps=timesteps, num_classes=num_classes,
                base_width=base_width)
    params, _ = pretrain_ddpm(ddpm, dataset=dataset, steps=steps,
                              ref_size=ref_size, seed=seed)
    return params, ddpm


class BatchedDDPMGenerator:
    """The real diffusion service behind `RunConfig(generator="ddpm")`.

    `generate` ignores the shared numpy Generator argument (interface
    compatibility with the oracle) and draws from the round-keyed stream
    instead; `rounds.py` threads the round index through
    `GenFVServer.generate`."""

    def __init__(self, params, ddpm: DDPM, seed: int,
                 sampler_steps: int = 50, obs=None):
        self.params = params
        self.ddpm = ddpm
        self.seed = int(seed)
        self.sampler_steps = int(sampler_steps)
        self.obs = obs if obs is not None else NULL_OBS

    def generate(self, labels: np.ndarray, rng: np.random.Generator,
                 round_idx: int = 0) -> np.ndarray:
        labels = np.asarray(labels, np.int32)
        n = len(labels)
        if n == 0:
            return np.empty((0, 32, 32, 3), np.float32)
        base_key = gen_round_key(self.seed, round_idx)
        bucket = bucket_size(n)
        obs = self.obs
        if obs.enabled:
            obs.count("gen/images", n)
            obs.observe("gen/pad_waste", bucket - n)
        # span key mirrors the sampler's jit cache key: first dispatch per
        # (bucket, steps) tags as "compile"
        with obs.span("round/generate/sample",
                      key=(bucket, self.sampler_steps), round=round_idx,
                      images=n, bucket=bucket,
                      steps=self.sampler_steps) as sp:
            imgs = sample_schedule(self.params, self.ddpm, base_key, labels,
                                   self.sampler_steps)
            sp.sync = imgs                  # host ndarray: already fenced
        return imgs


def make_ddpm_generator(dataset: str, num_classes: int, seed: int,
                        sampler_steps: int, obs=None) -> BatchedDDPMGenerator:
    """The runner's `generator="ddpm"` factory: pretrained (cached) params
    + round-keyed sampling streams. Reads the module-level budget constants
    at call time (tests shrink them via monkeypatch)."""
    params, ddpm = _pretrained_params(dataset, num_classes,
                                      RUNNER_TIMESTEPS, RUNNER_BASE_WIDTH,
                                      PRETRAIN_STEPS, PRETRAIN_REF,
                                      PRETRAIN_SEED)
    return BatchedDDPMGenerator(params, ddpm, seed=seed,
                                sampler_steps=sampler_steps, obs=obs)

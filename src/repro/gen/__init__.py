"""repro.gen — the AIGC dataplane (ROADMAP direction 2).

Serves SUBP4 generation schedules with the *real* class-conditional DDPM
(diffusion/ddpm.py) instead of the procedural oracle:

* `sampler`  — bucketed, per-image-keyed, strided ancestral sampling: every
  selected vehicle's per-label schedule rides ONE jitted dispatch, compiled
  once per (bucket, sampler_steps) shape;
* `service`  — `BatchedDDPMGenerator`, the round-keyed generator the round
  loop plugs in for `RunConfig(generator="ddpm")`;
* `calib`    — measured per-image sampling latency, cached per device in a
  ``repro.gen/calib/v1`` artifact, feeding the eq. 12-13 delay terms;
* `pretrain` — the reference-pool DDPM training loop + checkpoint.

Design notes: DESIGN.md §"AIGC dataplane".
"""
from repro.gen.calib import (CALIB_SCHEMA, MeasuredService, calibrated_service,
                             load_calibration, measure_t_per_image,
                             save_calibration)
from repro.gen.pretrain import (DDPM_CKPT_SCHEMA, load_pretrained,
                                pretrain_ddpm)
from repro.gen.sampler import sample_schedule, strided_timesteps
from repro.gen.service import (GEN_KEY, BatchedDDPMGenerator, gen_round_key,
                               make_ddpm_generator, runner_ddpm)

__all__ = [
    "BatchedDDPMGenerator", "CALIB_SCHEMA", "DDPM_CKPT_SCHEMA", "GEN_KEY",
    "MeasuredService", "calibrated_service", "gen_round_key",
    "load_calibration", "load_pretrained", "make_ddpm_generator",
    "measure_t_per_image", "pretrain_ddpm", "runner_ddpm", "sample_schedule",
    "save_calibration", "strided_timesteps",
]

"""Bucketed batched DDPM sampling with per-image key streams.

The seed's `ddpm_sample` threads ONE key chain over its whole batch
(`diffusion/ddpm.py::_sample_loop` splits the carry key once per step), so
the noise an image receives depends on which batch it rides in — sampling
vehicle schedules one label at a time and sampling them fused give
different images. This module makes the per-image computation a pure
function of (params, base_key, global image index, label):

* **per-image keys** — step noise for image ``i`` at denoising position
  ``s`` is drawn from ``fold_in(fold_in(base_key, i), s)`` (initial x_T
  uses the out-of-range position tag ``sampler_steps``). The UNet itself is
  per-sample (GroupNorm normalizes each image alone, attention attends
  within an image), so no op mixes batch rows and the math is independent
  of batch composition.
* **bucketing** — schedules pad to the power-of-two bucket family of
  `core/planner.py::bucket_size` (floor 4, shared with the fleet engine),
  so jit compiles once per (bucket, sampler_steps) instead of once per
  distinct schedule size, and the bucket family is bitwise-consistent on
  XLA:CPU (tests/test_gen.py pins batched == per-label-loop parity).
  Padded slots burn finite throwaway compute on label 0 and are sliced off.
* **strided schedule** — ``sampler_steps`` subsamples the full
  ``ddpm.timesteps`` noise schedule DDIM-style (eta=1: the ancestral
  posterior over the subsequence of alpha-bars), the quality/cost dial
  SUBP4 prices generation against.

Design notes: DESIGN.md §"AIGC dataplane".
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.planner import bucket_size
from repro.diffusion.ddpm import DDPM
from repro.diffusion.unet import unet_apply


def strided_timesteps(timesteps: int, sampler_steps: int) -> np.ndarray:
    """Ascending subsequence of ``sampler_steps`` timesteps out of
    ``[0, timesteps)``, endpoints included (the DDIM stride)."""
    if not 1 <= sampler_steps <= timesteps:
        raise ValueError(f"sampler_steps={sampler_steps} outside "
                         f"[1, {timesteps}]")
    if sampler_steps == 1:
        ts = np.array([timesteps - 1])
    else:
        ts = np.round(np.linspace(0.0, timesteps - 1, sampler_steps))
    ts = ts.astype(np.int64)
    if len(np.unique(ts)) != len(ts):   # linspace step >= 1: cannot happen
        raise ValueError("strided schedule collapsed to duplicate timesteps")
    return ts


def _per_image_noise(base_key, idx, pos_tag, shape):
    """[B]-batched N(0,1) noise keyed (base_key, global index, position)."""
    keys = jax.vmap(
        lambda i: jax.random.fold_in(jax.random.fold_in(base_key, i),
                                     pos_tag))(idx)
    return jax.vmap(lambda k: jax.random.normal(k, shape))(keys)


@partial(jax.jit, static_argnums=(1, 4))
def _sample_strided(params, ddpm: DDPM, base_key, y, sampler_steps: int,
                    idx):
    """Strided (eta=1) ancestral sampling, per-image keyed.

    y [B] int labels, idx [B] global image indices. Compiled once per
    (bucket B, sampler_steps); ddpm is static (frozen dataclass).
    """
    ts = jnp.asarray(strided_timesteps(ddpm.timesteps, sampler_steps))
    abars = ddpm.alpha_bars()
    B = y.shape[0]

    x = _per_image_noise(base_key, idx, jnp.int32(sampler_steps),
                         (32, 32, 3))

    def body(s, x):
        i = sampler_steps - 1 - s            # descending position in ts
        t = ts[i]
        abar_t = abars[t]
        abar_prev = jnp.where(i > 0, abars[ts[jnp.maximum(i - 1, 0)]], 1.0)
        tb = jnp.full((B,), t, jnp.int32)
        eps_hat = unet_apply(params, x, tb, y)
        x0_hat = (x - jnp.sqrt(1.0 - abar_t) * eps_hat) / jnp.sqrt(abar_t)
        # eta=1 posterior variance over the strided subsequence; at the
        # full stride this is the eq. (1) ancestral posterior
        var = ((1.0 - abar_prev) / (1.0 - abar_t)
               * (1.0 - abar_t / abar_prev))
        sigma = jnp.sqrt(jnp.maximum(var, 0.0))
        dir_x = jnp.sqrt(jnp.maximum(1.0 - abar_prev - sigma ** 2, 0.0))
        mean = jnp.sqrt(abar_prev) * x0_hat + dir_x * eps_hat
        noise = _per_image_noise(base_key, idx, i.astype(jnp.int32),
                                 (32, 32, 3))
        return mean + jnp.where(i > 0, sigma, 0.0) * noise

    x = lax.fori_loop(0, sampler_steps, body, x)
    return jnp.clip(x, -1.0, 1.0)


def sample_schedule(params, ddpm: DDPM, base_key, labels,
                    sampler_steps: int, start: int = 0,
                    bucket: int | None = None) -> np.ndarray:
    """Sample one (possibly multi-vehicle, multi-label) schedule in ONE
    jitted dispatch. Image ``j`` of the returned array is a pure function
    of (params, base_key, start + j, labels[j]) — callers slicing a big
    schedule into per-label or per-vehicle dispatches with matching
    ``start`` offsets reproduce it bitwise (tests/test_gen.py).

    `bucket` overrides the power-of-two padding (parity tests use it)."""
    labels = np.asarray(labels, np.int32)
    n = len(labels)
    if n == 0:
        return np.empty((0, 32, 32, 3), np.float32)
    kb = bucket_size(n) if bucket is None else int(bucket)
    if kb < n:
        raise ValueError(f"bucket {kb} smaller than schedule {n}")
    y = np.zeros(kb, np.int32)
    y[:n] = labels
    idx = np.arange(start, start + kb, dtype=np.uint32)
    out = _sample_strided(params, ddpm, base_key, jnp.asarray(y),
                          int(sampler_steps), jnp.asarray(idx))
    return np.asarray(out[:n], np.float32)

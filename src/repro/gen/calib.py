"""Measured per-image generation cost feeding the eq. 12-13 delay terms.

The seed prices eq. 48's b* with `DiffusionService`'s *assumed* cycle model
(t0 = steps * d_cycles / f_rsu). With a real sampler in the loop we can do
better: time the actual bucketed dispatch on this device and hand the
planner a `MeasuredService` whose ``t_per_image`` is the realized
steady-state (post-compile) wall-clock per image. `PlannerConsts` carries
t0 as a traced device scalar, so swapping the assumed service for a
measured one changes no jit cache keys — the planner recompiles nothing.

Measurements are cached in a ``repro.gen/calib/v1`` JSON artifact under
`artifact_dir()` (REPRO_ARTIFACTS-aware), keyed per (device backend, model
shape, sampler_steps, bucket): two runners on the same host share one
calibration, and a checkpoint-resumed runner restores the *recorded* t0
from the run checkpoint instead of re-measuring — re-measurement would
jitter the planner inputs and break bitwise resume (DESIGN.md §"AIGC
dataplane").
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass

import jax

from repro.diffusion.ddpm import DDPM
from repro.exp.artifacts import artifact_dir
from repro.gen.sampler import sample_schedule
from repro.gen.service import gen_round_key

CALIB_SCHEMA = "repro.gen/calib/v1"
CALIB_FILE = "gen_calib.json"

#: bucket the runner calibrates at — the steady-state schedule size for
#: default fleets (eq.-48 b* across ~8-16 selected vehicles).
CALIB_BUCKET = 16
CALIB_REPEATS = 3


@dataclass(frozen=True)
class MeasuredService:
    """Drop-in for `core.generation.DiffusionService` backed by a measured
    per-image latency. Frozen + hashable: it rides planner lru caches and
    sweep group keys like the assumed service does."""
    t_image: float                  # realized seconds per image
    steps: int = 50                 # sampler_steps it was measured at
    source: str = "measured"

    @property
    def t_per_image(self) -> float:
        """t0 in eq. (12)."""
        return self.t_image


def _calib_key(ddpm: DDPM, sampler_steps: int, bucket: int) -> str:
    dev = jax.devices()[0]
    return "/".join(map(str, (jax.default_backend(), dev.device_kind,
                              ddpm.timesteps, ddpm.num_classes,
                              ddpm.base_width, sampler_steps, bucket)))


def measure_t_per_image(params, ddpm: DDPM, sampler_steps: int,
                        bucket: int = CALIB_BUCKET,
                        repeats: int = CALIB_REPEATS) -> float:
    """Steady-state seconds per image of the bucketed dispatch: one warmup
    call absorbs compilation, then the best of `repeats` timed calls
    (min filters scheduler noise, the standard microbenchmark estimator)."""
    labels = [i % ddpm.num_classes for i in range(bucket)]
    key = gen_round_key(0, 0)
    sample_schedule(params, ddpm, key, labels, sampler_steps)   # warmup
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        sample_schedule(params, ddpm, key, labels, sampler_steps)
        best = min(best, time.perf_counter() - t0)
    return best / bucket


def _calib_path(directory: str | None = None) -> str:
    return os.path.join(artifact_dir(directory), CALIB_FILE)


def load_calibration(directory: str | None = None) -> dict:
    """The calibration table {key: {t_image, measured_at...}}; empty on a
    missing/foreign file."""
    path = _calib_path(directory)
    if not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if doc.get("schema") != CALIB_SCHEMA:
        return {}
    return doc.get("entries", {})


def save_calibration(entries: dict, directory: str | None = None) -> str:
    """Rewrite the calibration artifact (sorted keys: byte-stable for
    unchanged content, like every repro.exp artifact)."""
    from repro.obs import host_meta
    path = _calib_path(directory)
    doc = {"schema": CALIB_SCHEMA, "host": host_meta(), "entries": entries}
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, indent=1)
        f.write("\n")
    return path


def calibrated_service(params, ddpm: DDPM, sampler_steps: int,
                       bucket: int = CALIB_BUCKET,
                       directory: str | None = None) -> MeasuredService:
    """The measured service of (device, ddpm, sampler_steps, bucket):
    cache hit returns without touching the sampler, miss measures once and
    persists."""
    key = _calib_key(ddpm, sampler_steps, bucket)
    entries = load_calibration(directory)
    hit = entries.get(key)
    if hit is not None:
        return MeasuredService(t_image=float(hit["t_image"]),
                               steps=int(sampler_steps))
    t_image = measure_t_per_image(params, ddpm, sampler_steps, bucket)
    entries[key] = {"t_image": t_image, "bucket": int(bucket),
                    "sampler_steps": int(sampler_steps)}
    save_calibration(entries, directory)
    return MeasuredService(t_image=t_image, steps=int(sampler_steps))

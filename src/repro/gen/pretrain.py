"""Reference-pool DDPM pretraining (ROADMAP direction 2, paper Sec. III-B).

The RSU pretrains the class-conditional DDPM once on a small reference pool
(the paper's "AIGC model deployed at the RSU"), then serves every round's
SUBP4 schedule from it. The loop is DETERMINISTIC end to end — the pool,
the init key, the batch index stream, and the per-step loss keys are all
derived from ``SeedSequence((seed, lane, PRETRAIN_KEY))`` — so any process
(a fresh runner, a checkpoint resume, another sweep cell) that pretrains
with the same arguments reconstructs bitwise-identical params, and the
generator itself never needs to ride the runner checkpoint.

Checkpointing (``repro.gen/ddpm-ckpt/v1`` via `checkpoint/io.py`) is for
*amortization* across processes: `load_pretrained` validates the manifest
fingerprint (ddpm shape + pretrain budget) before restoring.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.io import read_manifest, restore_tree, save_tree
from repro.data.synthetic import DATASET_CLASSES, make_image_dataset
from repro.diffusion.ddpm import DDPM, ddpm_loss, make_ddpm
from repro.optim import adamw, constant_schedule

DDPM_CKPT_SCHEMA = "repro.gen/ddpm-ckpt/v1"

#: domain tag of the pretraining streams ("PRET"); lanes 0/1 split init
#: from batch selection.
PRETRAIN_KEY = 0x50524554


def _pretrain_fingerprint(ddpm: DDPM, dataset: str, steps: int,
                          ref_size: int, batch: int, lr: float,
                          seed: int) -> dict:
    return {"dataset": dataset, "timesteps": ddpm.timesteps,
            "num_classes": ddpm.num_classes, "base_width": ddpm.base_width,
            "beta_min": ddpm.beta_min, "beta_max": ddpm.beta_max,
            "steps": int(steps), "ref_size": int(ref_size),
            "batch": int(batch), "lr": float(lr), "seed": int(seed)}


def pretrain_ddpm(ddpm: DDPM, dataset: str = "cifar10", steps: int = 80,
                  ref_size: int = 512, batch: int = 32, lr: float = 2e-4,
                  seed: int = 0, ckpt_path: str | None = None,
                  obs=None) -> Tuple[dict, list]:
    """Train `ddpm` on a reference pool of `dataset`; returns
    (params, per-step losses). If `ckpt_path` is given the result is
    checkpointed there (and a matching existing checkpoint short-circuits
    the loop entirely)."""
    if dataset not in DATASET_CLASSES:
        raise ValueError(f"unknown dataset {dataset!r}")
    if DATASET_CLASSES[dataset] != ddpm.num_classes:
        raise ValueError(f"{dataset} has {DATASET_CLASSES[dataset]} classes"
                         f" but ddpm.num_classes={ddpm.num_classes}")
    fp = _pretrain_fingerprint(ddpm, dataset, steps, ref_size, batch, lr,
                               seed)
    if ckpt_path is not None:
        params = _try_restore(ckpt_path, fp)
        if params is not None:
            return params, []

    ss_init, ss_batch = (np.random.SeedSequence(
        entropy=(int(seed), lane, PRETRAIN_KEY)) for lane in (0, 1))
    init_key = jnp.asarray(ss_init.generate_state(2, np.uint32))
    params = make_ddpm(init_key, ddpm)

    imgs, labels = make_image_dataset(dataset, ref_size, seed=seed,
                                      noise=0.15)
    imgs, labels = jnp.asarray(imgs), jnp.asarray(labels)

    opt = adamw(constant_schedule(lr))
    opt_state = opt.init(params)

    @jax.jit
    def step(p, st, k, bi, bl):
        loss, g = jax.value_and_grad(ddpm_loss, argnums=0)(p, ddpm, k, bi,
                                                           bl)
        p, st = opt.update(g, st, p)
        return p, st, loss

    rng = np.random.default_rng(ss_batch)
    losses = []
    span = (obs.span("gen/pretrain", key=(ddpm.base_width, steps),
                     dataset=dataset, steps=steps)
            if obs is not None and obs.enabled else None)
    try:
        if span is not None:
            span.__enter__()
        for s in range(steps):
            ix = rng.integers(0, len(labels), batch)
            ks = jax.random.fold_in(init_key, s + 1)
            params, opt_state, loss = step(params, opt_state, ks, imgs[ix],
                                           labels[ix])
            losses.append(float(loss))
        if span is not None:
            span.sync = params
    finally:
        if span is not None:
            span.__exit__(None, None, None)

    params = jax.tree.map(np.asarray, params)
    if ckpt_path is not None:
        save_tree(ckpt_path, params,
                  metadata={"schema": DDPM_CKPT_SCHEMA, "pretrain": fp,
                            "final_loss": losses[-1] if losses else None})
    return params, losses


def _try_restore(path: str, fp: dict):
    import os
    if not path.endswith(".npz"):
        path += ".npz"
    if not os.path.exists(path):
        return None
    meta = read_manifest(path)["metadata"]
    if meta.get("schema") != DDPM_CKPT_SCHEMA or meta.get("pretrain") != fp:
        return None
    return restore_tree(path)


def load_pretrained(path: str, ddpm: DDPM) -> dict:
    """Restore a ``repro.gen/ddpm-ckpt/v1`` checkpoint, validating schema
    and model-shape fingerprint against `ddpm`."""
    if not path.endswith(".npz"):
        path += ".npz"
    meta = read_manifest(path)["metadata"]
    if meta.get("schema") != DDPM_CKPT_SCHEMA:
        raise ValueError(f"not a DDPM checkpoint: schema="
                         f"{meta.get('schema')!r}")
    fp = meta.get("pretrain", {})
    for field in ("timesteps", "num_classes", "base_width"):
        if fp.get(field) != getattr(ddpm, field):
            raise ValueError(f"checkpoint {field}={fp.get(field)} does not "
                             f"match ddpm.{field}={getattr(ddpm, field)}")
    return restore_tree(path)

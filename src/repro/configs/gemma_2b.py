"""Gemma 2B [arXiv:2403.08295] — GeGLU, head_dim=256, MQA (kv=1)."""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,            # MQA
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    mlp_type="geglu",
    pattern=(ATTN_GLOBAL,),
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed=True,
    supports_long_context=False,
    long_context_note="pure full attention; long_500k decode skipped per spec",
    citation="arXiv:2403.08295",
)

"""RecurrentGemma-9B (Griffin) [arXiv:2402.19427] — RG-LRU + local attention,
pattern 2 recurrent : 1 local-attn, MQA kv=1, window 2048.
Sub-quadratic -> runs long_500k.
"""
from repro.configs.base import ModelConfig, BLOCK_RGLRU, ATTN_LOCAL

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,             # 38 = 12x(rglru,rglru,local) + (rglru,rglru)
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,            # MQA on the attention blocks
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    mlp_type="geglu",
    pattern=(BLOCK_RGLRU, BLOCK_RGLRU, ATTN_LOCAL),
    sliding_window=2048,
    lru_width=4096,
    conv_kernel=4,
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed=True,
    supports_long_context=True,
    long_context_note="RG-LRU recurrence + 2048-window attention; long_500k runs",
    citation="arXiv:2402.19427",
)

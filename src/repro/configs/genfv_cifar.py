"""Paper-faithful GenFV experiment config (Section VI): ResNet-18-style CNN
on 32x32 class-conditional image datasets with Dirichlet non-IID partitions.
"""
from dataclasses import dataclass

from repro.configs.base import GenFVConfig


@dataclass(frozen=True)
class CNNConfig:
    name: str
    num_classes: int
    image_size: int = 32
    channels: int = 3
    # ResNet-18 stage widths (paper uses ResNet-18; we keep the same topology,
    # width-scalable for smoke tests).
    stem_width: int = 64
    stage_blocks: tuple = (2, 2, 2, 2)
    width_mult: float = 1.0


DATASETS = {
    # name -> (num_classes, train_size, test_size) mirroring the paper's three
    "cifar10": (10, 50_000, 10_000),
    "cifar100": (100, 50_000, 10_000),
    "gtsrb": (43, 39_209, 12_630),
}


def cnn_config(dataset: str = "cifar10", width_mult: float = 1.0) -> CNNConfig:
    classes, _, _ = DATASETS[dataset]
    return CNNConfig(name=f"resnet18-{dataset}", num_classes=classes,
                     width_mult=width_mult)


# Table I: \hat{EMD} thresholds per dataset and Dirichlet alpha.
EMD_THRESHOLDS = {
    "cifar10": {0.1: 1.5, 0.3: 1.2, 0.5: 1.0, 1.0: 0.8},
    "cifar100": {0.1: 1.5, 0.3: 1.2, 0.5: 1.0, 1.0: 0.8},
    "gtsrb": {0.1: 1.5, 0.3: 1.3, 0.5: 1.2, 1.0: 1.0},
}


def genfv_config(dataset: str = "cifar10", alpha: float = 0.1,
                 **overrides) -> GenFVConfig:
    defaults = dict(dirichlet_alpha=alpha,
                    emd_threshold=EMD_THRESHOLDS[dataset][alpha])
    defaults.update(overrides)
    return GenFVConfig(**defaults)

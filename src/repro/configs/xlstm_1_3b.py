"""xLSTM 1.3B [arXiv:2405.04517] — sLSTM + mLSTM blocks (xLSTM[7:1]),
d_ff=0 (the block's up-projection plays the MLP role), 4 heads,
recurrent O(1) decode state -> runs long_500k.
"""
from repro.configs.base import ModelConfig, BLOCK_MLSTM, BLOCK_SLSTM

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                    # per assignment: blocks carry their own projections
    vocab_size=50304,
    head_dim=512,              # inner = d_model*proj_factor over 4 heads... set by block
    proj_factor=2.0,
    conv_kernel=4,
    # xLSTM[7:1]: one sLSTM block per 7 mLSTM blocks (48 = 6 groups of 8)
    pattern=(BLOCK_MLSTM,) * 7 + (BLOCK_SLSTM,),
    norm="layernorm",
    tie_embeddings=True,
    supports_long_context=True,
    long_context_note="recurrent state decode, O(1) per token; long_500k runs",
    citation="arXiv:2405.04517",
)

"""Gemma-2 9B [arXiv:2408.00118] — local/global alternating attention,
logit soft-capping, GeGLU, GQA kv=8, head_dim=256.
"""
from repro.configs.base import ModelConfig, ATTN_LOCAL, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=256000,
    head_dim=256,
    mlp_type="geglu",
    pattern=(ATTN_LOCAL, ATTN_GLOBAL),   # alternate local(4096) / global
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed=True,
    supports_long_context=True,
    long_context_note=(
        "long_500k decode runs with the documented variant: global layers fall "
        "back to the 4096 sliding window beyond 32k context (block-local "
        "serving mode), making decode sub-quadratic. Recorded in DESIGN.md §5."),
    citation="arXiv:2408.00118",
)

"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — dense, QKV bias, MHA kv=16."""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,             # Qwen's signature QKV bias
    mlp_type="swiglu",
    pattern=(ATTN_GLOBAL,),
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_note="pure full attention; long_500k decode skipped per spec",
    citation="hf:Qwen/Qwen1.5-0.5B",
)

"""Grok-1 314B [hf:xai-org/grok-1] — MoE, 8 experts top-2, attn logit cap."""
from repro.configs.base import ModelConfig, MoEConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    mlp_type="geglu",
    attn_softcap=30.0,         # grok caps attention logits (30 * tanh(x/30))
    final_softcap=None,
    pattern=(ATTN_GLOBAL,),
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=8, experts_per_token=2, d_expert=32768),
    supports_long_context=False,
    long_context_note="full attention; long_500k decode skipped per spec",
    citation="hf:xai-org/grok-1",
)

"""Architecture registry: `get_config("<arch-id>")` and shape lookup."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    ATTN_GLOBAL, ATTN_LOCAL, BLOCK_MLSTM, BLOCK_RGLRU, BLOCK_SLSTM,
    GenFVConfig, HardwareSpec, INPUT_SHAPES, InputShape, ModelConfig,
    MoEConfig, V5E,
)

# arch-id -> module name under repro.configs
_ARCH_MODULES = {
    "minicpm-2b": "minicpm_2b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "gemma2-9b": "gemma2_9b",
    "whisper-tiny": "whisper_tiny",
    "grok-1-314b": "grok_1_314b",
    "gemma-2b": "gemma_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}

_cache: Dict[str, ModelConfig] = {}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _cache:
        if arch not in _ARCH_MODULES:
            raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
        _cache[arch] = mod.CONFIG
    return _cache[arch]


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]

"""OLMoE-1B-7B [arXiv:2409.02060] — MoE, 64 experts top-8, d_expert=1024."""
from repro.configs.base import ModelConfig, MoEConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,                 # per-expert FFN width
    vocab_size=50304,
    head_dim=128,
    mlp_type="swiglu",
    pattern=(ATTN_GLOBAL,),
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=64, experts_per_token=8, d_expert=1024),
    supports_long_context=False,
    long_context_note="full attention; long_500k decode skipped per spec",
    citation="arXiv:2409.02060",
)

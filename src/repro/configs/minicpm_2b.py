"""MiniCPM-2B [arXiv:2404.06395] — dense llama-like, MHA, WSD schedule."""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,          # full MHA per assignment (GQA kv=36)
    d_ff=5760,
    vocab_size=122753,
    head_dim=64,
    mlp_type="swiglu",
    pattern=(ATTN_GLOBAL,),
    rope_theta=10000.0,
    tie_embeddings=True,
    scale_embed=True,          # MiniCPM scales embeddings / residuals (mu-p style)
    schedule="wsd",            # Warmup-Stable-Decay, the paper's signature schedule
    supports_long_context=False,
    long_context_note="pure full attention; long_500k decode skipped per spec",
    citation="arXiv:2404.06395",
)

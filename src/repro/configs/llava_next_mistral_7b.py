"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf].

VLM: the vision tower (CLIP-ViT-L + anyres tiling + 2-layer MLP projector)
is a STUB per the assignment carve-out — `input_specs()` supplies precomputed
patch embeddings (anyres: base 576 tokens + up to 4 tiles -> 2880 tokens).
The Mistral-7B language backbone below is fully implemented.
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,            # GQA
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    mlp_type="swiglu",
    pattern=(ATTN_GLOBAL,),    # mistral-v0.2 backbone: no sliding window
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    modality="vision",
    frontend_tokens=2880,      # anyres: 576 base + 4x576 tiles
    supports_long_context=False,
    long_context_note="full attention backbone; long_500k decode skipped per spec",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

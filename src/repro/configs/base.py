"""Config system for repro: model architectures, input shapes, hardware.

Every assigned architecture is a `ModelConfig` (exact sizes from its source
paper / model card, cited in the per-arch file). `InputShape` captures the
four assigned workload shapes. `HardwareSpec` carries the TPU v5e constants
used by the roofline analysis (these are *target* numbers; the container
runs on CPU and only lowers/compiles against them).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer kinds used in repeating block patterns.
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"    # full causal attention
ATTN_LOCAL = "local"      # sliding-window causal attention
BLOCK_MLSTM = "mlstm"     # xLSTM matrix-memory block
BLOCK_SLSTM = "slstm"     # xLSTM scalar-memory block
BLOCK_RGLRU = "rglru"     # RG-LRU recurrent block (Griffin)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # d_ff of each expert (may differ from the dense d_ff notion)
    d_expert: int
    router_aux_loss: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. All sizes are the FULL assigned sizes; reduced smoke
    variants are derived with `.reduced()`."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    head_dim: Optional[int] = None   # default: d_model // num_heads
    # --- attention options ---------------------------------------------
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None     # tanh soft-cap on attention logits
    final_softcap: Optional[float] = None    # tanh soft-cap on LM-head logits
    sliding_window: Optional[int] = None     # window for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    # Repeating block pattern; tiled to num_layers. ("global",) = vanilla.
    pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    # --- mlp ---------------------------------------------------------------
    mlp_type: str = "swiglu"                 # swiglu | geglu | gelu
    # --- moe ----------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- ssm / hybrid --------------------------------------------------------
    lru_width: Optional[int] = None          # RG-LRU recurrence width
    conv_kernel: int = 4                     # temporal-conv width in recurrent blocks
    proj_factor: float = 2.0                 # xLSTM up-projection factor
    # --- embeddings / norm ----------------------------------------------------
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    tie_embeddings: bool = True
    scale_embed: bool = False                # gemma-style sqrt(d_model) embed scaling
    # --- enc-dec (audio) -------------------------------------------------------
    encoder_layers: int = 0                  # >0 => encoder-decoder (whisper)
    encoder_seq: int = 1500                  # post-conv encoder frames (whisper stub)
    # --- modality frontend stub -------------------------------------------------
    modality: str = "text"                   # text | vision | audio
    # number of (precomputed) frontend embedding tokens prepended for vlm
    frontend_tokens: int = 0
    # --- training ------------------------------------------------------------------
    schedule: str = "cosine"                 # cosine | wsd
    # Pad the embedding/unembedding vocab up to a multiple (0 = off). Padded
    # logit columns are masked to -1e9 in unembed; used when the true vocab
    # does not divide the tensor-parallel axis (§Perf hillclimb 2).
    pad_vocab_multiple: int = 0
    # --- long-context policy ----------------------------------------------------------
    # Whether serve_step at 500k is runnable (sub-quadratic / windowed decode).
    supports_long_context: bool = False
    long_context_note: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # ------------------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kinds, pattern tiled to num_layers."""
        reps = -(-self.num_layers // len(self.pattern))
        return (self.pattern * reps)[: self.num_layers]

    @property
    def padded_vocab_size(self) -> int:
        if self.pad_vocab_multiple <= 0:
            return self.vocab_size
        m = self.pad_vocab_multiple
        return -(-self.vocab_size // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_recurrent_decode(self) -> bool:
        """True if decode state is recurrent (O(1)) rather than a KV cache."""
        return self.family == "ssm"

    # ------------------------------------------------------------------
    def reduced(self, num_layers: int = 2, d_model: int = 256,
                vocab: int = 512, seq_cap: int = 128) -> "ModelConfig":
        """Smoke-test variant of the same family: <=2 layers, d_model<=512,
        <=4 experts, same block pattern / options."""
        d_model = min(d_model, 512)
        heads = max(1, min(self.num_heads, 4))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        head_dim = max(8, d_model // heads)
        moe = None
        if self.moe is not None:
            k = min(self.moe.experts_per_token, 2)
            moe = MoEConfig(num_experts=4, experts_per_token=k,
                            d_expert=max(8, d_model // 2),
                            router_aux_loss=self.moe.router_aux_loss)
        # Shrink the block pattern to one instance of each distinct kind so the
        # smoke variant keeps every code path while staying at ~2 layers.
        pattern = tuple(dict.fromkeys(self.pattern))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            pattern=pattern,
            num_layers=max(num_layers, len(pattern)),
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=0 if self.d_ff == 0 else max(16, d_model * 2),
            vocab_size=min(self.vocab_size, vocab),
            sliding_window=None if self.sliding_window is None else min(self.sliding_window, seq_cap // 2),
            lru_width=None if self.lru_width is None else d_model,
            moe=moe,
            encoder_layers=0 if self.encoder_layers == 0 else 2,
            encoder_seq=min(self.encoder_seq, 64),
            frontend_tokens=min(self.frontend_tokens, 16),
        )

    # ------------------------------------------------------------------
    # Parameter counting (used by roofline + memory budgeting).
    def param_count(self) -> int:
        return sum(self._param_terms().values())

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        terms = self._param_terms()
        if self.moe is not None:
            frac = self.moe.experts_per_token / self.moe.num_experts
            terms["moe_experts"] = int(terms["moe_experts"] * frac)
        return sum(terms.values())

    def _param_terms(self) -> dict:
        d, hd = self.d_model, self.head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        terms = {"embed": self.vocab_size * d}
        if not self.tie_embeddings:
            terms["lm_head"] = self.vocab_size * d
        attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * hd
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        n_attn = n_mlp = n_rec = n_moe = 0
        for kind in self.layer_kinds:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                n_attn += 1
                if self.moe is not None:
                    n_moe += 1
                elif self.d_ff > 0:
                    n_mlp += 1
            elif kind == BLOCK_RGLRU:
                n_rec += 1
                n_mlp += 1
            elif kind in (BLOCK_MLSTM, BLOCK_SLSTM):
                n_rec += 1
        terms["attn"] = n_attn * attn
        terms["mlp"] = n_mlp * mlp
        if self.moe is not None:
            e = self.moe
            expert = 3 * d * e.d_expert if self.mlp_type in ("swiglu", "geglu") else 2 * d * e.d_expert
            terms["moe_experts"] = n_moe * e.num_experts * expert
            terms["moe_router"] = n_moe * d * e.num_experts
        if n_rec:
            if self.family == "ssm":
                # xLSTM mLSTM block: up-proj 2x, q/k/v projections, out-proj.
                pf = self.proj_factor
                inner = int(d * pf)
                per = d * inner * 2 + 3 * inner * inner // max(self.num_heads, 1) + inner * d
                terms["recurrent"] = n_rec * per
            else:
                w = self.lru_width or d
                # Griffin recurrent block: in/out proj + gates + conv.
                per = 2 * d * w + 3 * w + w * self.conv_kernel + w * d + 2 * w * w
                terms["recurrent"] = n_rec * per
        if self.encoder_layers:
            terms["encoder"] = self.encoder_layers * (attn * 2 + mlp)  # self+cross approx
        terms["norms"] = 2 * self.num_layers * d + d
        return terms


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Target hardware (TPU v5e), used only for roofline math.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class HardwareSpec:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    hbm_bytes: float = 16 * 2**30     # capacity per chip
    ici_bw: float = 50e9              # bytes/s per link


V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# Streaming RSU round policy (fl/stream.py). Grouped here (rather than on
# GenFVConfig) because these are SERVICE knobs — how the RSU commits rounds —
# not physical-layer parameters; RunConfig carries one as `stream`.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StreamConfig:
    """Quorum / retry / cadence policy for the event-driven streaming round
    engine (`repro.fl.stream.StreamEngine`). Frozen + flat so it rides inside
    the frozen `RunConfig` (hashable grid cells, JSON-able checkpoints).

    The defaults reproduce the synchronous round loop exactly: quorum=1.0
    commits on the last planned upload, cadence 0 fires rounds back-to-back,
    and with no fault schedule attached no retry is ever scheduled
    (tests/test_stream.py pins the bitwise sync parity).
    """
    # Fraction of the round's SELECTED uploads that must arrive before the
    # RSU commits (quorum count = ceil(quorum * K), floored at 1).
    quorum: float = 1.0
    # Minimum virtual seconds between consecutive round starts; 0 = a new
    # round fires the instant the previous one commits (sync semantics).
    cadence_s: float = 0.0
    # Degradation rung 1: when the quorum misses the planned close t_bar,
    # the deadline is extended ONCE to t_bar * (1 + deadline_slack).
    deadline_slack: float = 0.25
    # Upload retries after a failed (deep-faded) attempt, with capped
    # exponential backoff: wait min(backoff * 2^a, cap) before attempt a+1.
    retry_budget: int = 2
    retry_backoff_s: float = 0.25
    retry_backoff_cap_s: float = 2.0
    # Merge-on-arrival discount for uploads landing after their round's
    # commit: weight ∝ size * discount^age, dropped past max_staleness
    # rounds (mirrors FaultSpec's recovery policy, but streaming needs it
    # even without a fault schedule — quorum < 1 makes on-time stragglers).
    staleness_discount: float = 0.5
    max_staleness: int = 2

    def __post_init__(self):
        if not 0.0 < self.quorum <= 1.0:
            raise ValueError(f"quorum={self.quorum} outside (0, 1]")
        if self.cadence_s < 0.0:
            raise ValueError("cadence_s must be >= 0")
        if self.deadline_slack < 0.0:
            raise ValueError("deadline_slack must be >= 0")
        if self.retry_budget < 0:
            raise ValueError("retry_budget must be >= 0")
        if self.retry_backoff_s <= 0.0:
            raise ValueError("retry_backoff_s must be > 0")
        if self.retry_backoff_cap_s < self.retry_backoff_s:
            raise ValueError("retry_backoff_cap_s must be >= retry_backoff_s")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in (0, 1]")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")

    def to_payload(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict) -> "StreamConfig":
        return cls(**payload)


# ---------------------------------------------------------------------------
# FL / GenFV experiment config (paper Section VI defaults).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GenFVConfig:
    num_vehicles: int = 40            # vehicles in RSU range (Poisson mean)
    num_subcarriers: int = 20         # M
    # Per-subchannel bandwidth. The paper fixes M=20 subcarriers but leaves W
    # unspecified; 10 MHz makes a ResNet-18 upload ~2.3 s on one subcarrier,
    # matching the paper's t_max ~ 3 s operating point (Fig. 7).
    subcarrier_bw: float = 1e7        # W per subchannel (Hz)
    noise_power_dbm: float = -174.0   # N0
    phi_min: float = 0.1              # W
    phi_max: float = 1.0              # W
    rsu_tx_power_dbm: float = 40.0
    path_loss_exp: float = 2.0        # gamma
    unit_channel_gain: float = 1e-5   # h0
    rsu_radius: float = 500.0         # r (m)
    rsu_road_offset: float = 10.0     # e (m)
    v_max: float = 120.0              # km/h
    v_min: float = 10.0
    m_max: int = 60                   # max vehicles on road segment
    sigma_k: float = 0.1              # sigma = k * v_bar
    t_max: float = 3.0                # max round time (s)
    # Per-round energy budget. Unspecified in the paper; the eq. 6-8 GPU
    # model puts local training alone at 6-19 J, so 20 J makes the energy
    # constraint bind for slow-GPU vehicles without rejecting the fleet.
    e_max: float = 20.0               # per-round energy budget (J)
    local_steps: int = 4              # h
    # RSU augmented-model steps per round = rsu_steps_factor * h. The RSU GPU
    # is ~8x a vehicle GPU (Sec. IV-A5), so it fits more SGD inside the
    # straggler window it is already waiting through.
    rsu_steps_factor: int = 4
    lr: float = 1e-4
    batch_size: int = 64
    dirichlet_alpha: float = 0.1
    emd_threshold: float = 1.5        # \hat{EMD} (Table I)
    # diffusion service
    diffusion_steps: int = 50         # I
    gen_batch: int = 64               # images per generation batch
    # --- SUBP2-4 solver hyperparameters (Algorithms 1-3) -------------------
    # Read by BOTH the numpy reference solvers (core/bandwidth.py,
    # core/power.py) and the jitted batched planner (core/planner.py); the
    # defaults reproduce the seed's hard-coded values bitwise.
    bw_l_min: float = 0.05            # SUBP2 fractional-subcarrier floor
    bw_step: float = 0.05             # Algorithm 1 subgradient step
    bw_max_iter: int = 500            # Algorithm 1 iteration cap
    bw_tol: float = 1e-5              # Algorithm 1 fixed-point tolerance
    sca_max_iter: int = 50            # Algorithm 2 SCA iteration cap
    sca_eps: float = 1e-4             # Algorithm 2 fixed-point tolerance
    bcd_eps: float = 1e-3             # Algorithm 3 outer BCD tolerance
    bcd_max_iter: int = 20            # Algorithm 3 outer BCD cap
    # --- repro.sim persistent-world layer (Sec. V-A2 made stateful) --------
    # Poisson arrival rate at the coverage edges (veh/s, both directions
    # combined). The default keeps the equilibrium population near
    # num_vehicles for the nominal geometry/speeds. Ignored by the legacy
    # memoryless per-round sampler.
    arrival_rate: float = 1.1
    # AR(1) log-normal shadowing on the uplink channel gain h0: stationary
    # std-dev (dB) and decorrelation time constant (s). 0 dB disables
    # shadowing, which is the legacy memoryless channel.
    shadow_sigma_db: float = 0.0
    shadow_corr_time: float = 20.0

"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder, conv frontend STUB.

The mel-spectrogram + 2x conv1d feature extractor is a stub per the
assignment carve-out: `input_specs()` supplies precomputed frame embeddings
(1500 frames x 384). Encoder self-attn + decoder self/cross-attn are real.
Uses LayerNorm and learned positions (sinusoidal enc stub folded into the
frame embeddings).
"""
from repro.configs.base import ModelConfig, ATTN_GLOBAL

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    encoder_seq=1500,          # 30s audio -> 1500 frames post-conv
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    mlp_type="gelu",
    norm="layernorm",
    pattern=(ATTN_GLOBAL,),
    tie_embeddings=True,
    modality="audio",
    supports_long_context=False,
    long_context_note=(
        "enc-dec with full attention and 448-token decoder context in the "
        "source model; long_500k skipped per spec (decode_32k exercised "
        "mechanically against the assigned cache length)."),
    citation="arXiv:2212.04356",
)

"""Procedural datasets (no internet in this container — DESIGN.md §2).

Image datasets mirror the paper's three benchmarks in class count and size:
cifar10 (10), cifar100 (100), gtsrb (43). Each class is a deterministic
low-frequency pattern; samples are pattern + translation + noise, so the
class structure is learnable by a CNN and by the class-conditional DDPM,
and *label distributions* (what the paper's EMD policy consumes) behave
exactly like the real thing.

Token datasets provide LM training streams for the assigned backbones.
"""
from __future__ import annotations

import zlib
from functools import lru_cache
from typing import Tuple

import numpy as np

DATASET_CLASSES = {"cifar10": 10, "cifar100": 100, "gtsrb": 43}
IMG = 32


def _stable_seed(*key) -> int:
    """Process-independent pattern seed. Builtin `hash()` is salted by
    PYTHONHASHSEED, which made class patterns differ between interpreter
    runs — harmless for single-process golden tests but fatal for
    cross-process checkpoint resume (and the occasional hash seed drew
    near-degenerate class pairs)."""
    return zlib.crc32("/".join(map(str, key)).encode())


def _wave_pattern(seed: int, f_lo: float, f_hi: float, n_waves: int = 4
                  ) -> np.ndarray:
    rng = np.random.default_rng(seed % (2 ** 31))
    yy, xx = np.mgrid[0:IMG, 0:IMG].astype(np.float64) / IMG
    img = np.zeros((IMG, IMG, 3))
    for _ in range(n_waves):
        fx, fy = rng.uniform(f_lo, f_hi, 2)
        px, py = rng.uniform(0, 2 * np.pi, 2)
        amp = rng.uniform(0.3, 1.0, 3)
        wave = np.sin(2 * np.pi * (fx * xx + px)) * np.cos(2 * np.pi * (fy * yy + py))
        img += wave[..., None] * amp
    img /= np.abs(img).max() + 1e-9
    return img.astype(np.float32)


@lru_cache(maxsize=None)
def _coarse_pattern(name: str, cls: int) -> np.ndarray:
    """Low-frequency 'shape' component — SHARED between class pairs
    (cls // 2), mimicking the coarse structure a generative model captures."""
    return _wave_pattern(_stable_seed(name, "coarse", cls // 2), 0.5, 2.5)


@lru_cache(maxsize=None)
def _fine_pattern(name: str, cls: int) -> np.ndarray:
    """High-frequency 'texture' component — unique per class. This is the
    detail that separates paired classes; the AIGC oracle cannot reproduce
    it (fl/generator.py), giving AIGC-only training its accuracy ceiling
    (paper Fig. 10-12)."""
    return _wave_pattern(_stable_seed(name, "fine", cls), 6.0, 12.0)


@lru_cache(maxsize=None)
def _class_pattern(name: str, cls: int) -> np.ndarray:
    """Deterministic pattern for (dataset, class): coarse shared shape +
    class-unique fine texture, [32,32,3] in [-1,1]."""
    img = 0.6 * _coarse_pattern(name, cls) + 0.4 * _fine_pattern(name, cls)
    return (img / (np.abs(img).max() + 1e-9)).astype(np.float32)


def make_image_dataset(name: str, n: int, seed: int = 0,
                       noise: float = 0.25,
                       labels: np.ndarray | None = None
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (images [n,32,32,3] float32 in [-1,1], labels [n] int32)."""
    classes = DATASET_CLASSES[name]
    rng = np.random.default_rng(seed)
    if labels is None:
        labels = rng.integers(0, classes, size=n)
    labels = np.asarray(labels, np.int32)
    imgs = np.empty((n, IMG, IMG, 3), np.float32)
    shifts = rng.integers(-3, 4, size=(n, 2))
    eps = rng.normal(0.0, noise, size=(n, IMG, IMG, 3)).astype(np.float32)
    for i, c in enumerate(labels):
        p = np.roll(_class_pattern(name, int(c)), shifts[i], axis=(0, 1))
        imgs[i] = np.clip(0.8 * p + eps[i], -1.0, 1.0)
    return imgs, labels


def make_token_dataset(vocab: int, n_tokens: int, seed: int = 0,
                       order: int = 2) -> np.ndarray:
    """Markov token stream with learnable structure (for LM smoke training)."""
    rng = np.random.default_rng(seed)
    # sparse deterministic transition: next = (a*prev + b) % vocab with noise
    a, b = int(rng.integers(2, 97)), int(rng.integers(1, vocab))
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(0, vocab)
    noise = rng.random(n_tokens) < 0.1
    rand = rng.integers(0, vocab, size=n_tokens)
    for i in range(1, n_tokens):
        toks[i] = rand[i] if noise[i] else (a * int(toks[i - 1]) + b) % vocab
    return toks


def batch_tokens(tokens: np.ndarray, batch: int, seq: int, step: int,
                 ) -> dict:
    """Slice a [batch, seq+1] window -> {tokens, targets, mask}."""
    need = batch * (seq + 1)
    start = (step * need) % max(len(tokens) - need, 1)
    chunk = tokens[start:start + need].reshape(batch, seq + 1)
    return {"tokens": chunk[:, :-1].astype(np.int32),
            "targets": chunk[:, 1:].astype(np.int32),
            "mask": np.ones((batch, seq), np.float32)}

"""Dirichlet non-IID partitioning (paper Sec. VI-A1): lower alpha =>
more heterogeneous per-vehicle label distributions."""
from __future__ import annotations

from typing import List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float,
                        rng: np.random.Generator,
                        min_size: int = 8) -> List[np.ndarray]:
    """Split sample indices across clients with per-class Dir(alpha) shares.

    Returns a list of index arrays (one per client, shuffled)."""
    labels = np.asarray(labels)
    classes = int(labels.max()) + 1
    for _ in range(100):
        idx_per_client: List[list] = [[] for _ in range(n_clients)]
        for c in range(classes):
            idx_c = np.flatnonzero(labels == c)
            rng.shuffle(idx_c)
            props = rng.dirichlet(np.full(n_clients, alpha))
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for cl, part in enumerate(np.split(idx_c, cuts)):
                idx_per_client[cl].extend(part.tolist())
        sizes = [len(ix) for ix in idx_per_client]
        if min(sizes) >= min_size:
            break
    out = []
    for ix in idx_per_client:
        arr = np.array(ix, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out

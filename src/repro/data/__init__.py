from repro.data.synthetic import make_image_dataset, make_token_dataset, DATASET_CLASSES
from repro.data.partition import dirichlet_partition

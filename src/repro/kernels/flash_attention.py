"""Flash attention Pallas TPU kernel (target: v5e MXU; validated with
interpret=True on CPU).

Canonical TPU structure: 4D grid (batch, q_head, q_block, kv_block) with the
kv dimension sequential ("arbitrary") so fp32 accumulators live in VMEM
scratch across kv steps; q/k/v blocks are VMEM tiles selected by BlockSpec
index maps (MXU-aligned: block_q x head_dim and block_k x head_dim with
head_dim a multiple of 64/128 on all assigned archs).

Features needed by the assigned architectures: GQA (kv head = q head // g,
folded into the k/v index_map), causal + sliding-window masking, logit
soft-capping (gemma2/grok), and position-based masking (-1 = empty cache
slot; ring-buffer decode caches come in un-rotated).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref,      # inputs
            o_ref,                                     # output
            acc_ref, m_ref, l_ref,                     # VMEM scratch
            *, causal: bool, window: Optional[int],
            softcap: Optional[float], n_kv: int, block_q: int, block_k: int):
    kv_idx = pl.program_id(3)

    @pl.when(kv_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, ...].astype(jnp.float32)              # [bq, hd]
    k = k_ref[0, ...].astype(jnp.float32)              # [bk, hd]
    v = v_ref[0, ...].astype(jnp.float32)
    qp = qp_ref[...]                                   # [bq] int32
    kp = kp_ref[...]                                   # [bk] int32

    hd = q.shape[-1]
    s = jax.lax.dot_general(q * (hd ** -0.5), k,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = (kp >= 0)[None, :]
    if causal:
        rel = qp[:, None] - kp[None, :]
        valid &= rel >= 0
        if window is not None:
            valid &= rel < window
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur
    l_ref[...] = l_cur

    @pl.when(kv_idx == n_kv - 1)
    def _out():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """q: [B,Sq,nq,hd]; k,v: [B,Skv,nkv,hd]; q_pos: [B,Sq]; kv_pos: [B,Skv].

    Returns [B,Sq,nq,hd] in q.dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv

    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)),
                        constant_values=-(2 ** 30))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    Sqp, Skvp = Sq + pad_q, Skv + pad_k
    n_q, n_kv = Sqp // block_q, Skvp // block_k

    # layout: [B, heads, S, hd] so blocks are (1, 1, block, hd) VMEM tiles
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)

    grid = (B, nq, n_q, n_kv)
    kernel = functools.partial(_kernel, causal=causal, window=window,
                               softcap=softcap, n_kv=n_kv,
                               block_q=block_q, block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q), lambda b, h, i, j: (b, i)),      # q_pos
            pl.BlockSpec((None, block_k), lambda b, h, i, j: (b, j)),      # kv_pos
            pl.BlockSpec((None, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),                 # q
            pl.BlockSpec((None, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),            # k
            pl.BlockSpec((None, 1, block_k, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),            # v
        ],
        out_specs=pl.BlockSpec((None, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),    # acc
            pltpu.VMEM((block_q,), jnp.float32),       # m
            pltpu.VMEM((block_q,), jnp.float32),       # l
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q_pos, kv_pos, qT, kT, vT)

    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq]

"""Pure-jnp oracles for the Pallas kernels (naive, O(Sq x Skv) — used only
at test shapes)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def flash_attention_ref(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """q: [B,Sq,nq,hd]; k,v: [B,Skv,nkv,hd]; positions int32 (-1 = empty).

    Returns [B,Sq,nq,hd] in q.dtype. fp32 softmax."""
    B, Sq, nq, hd = q.shape
    nkv = k.shape[2]
    g = nq // nkv
    qg = q.reshape(B, Sq, nkv, g, hd).astype(jnp.float32) * (hd ** -0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    valid = kv_pos[:, None, None, None, :] >= 0
    if causal:
        rel = q_pos[:, None, None, :, None] - kv_pos[:, None, None, None, :]
        valid &= rel >= 0
        if window is not None:
            valid &= rel < window
    s = jnp.where(valid, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid kv produce uniform p over masked lanes; zero them
    any_valid = jnp.any(valid, axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, nq, hd).astype(q.dtype)


def rglru_scan_ref(log_a, b):
    """h_t = exp(log_a_t) h_{t-1} + b_t along axis 1. [B,S,W] fp32."""
    def step(h, xs):
        la, bb = xs
        h = jnp.exp(la) * h + bb
        return h, h

    _, hs = jax.lax.scan(step, jnp.zeros_like(b[:, 0]),
                         (log_a.transpose(1, 0, 2), b.transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)

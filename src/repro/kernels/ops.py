"""Jit'd public wrappers for the Pallas kernels.

`impl="pallas"` paths in models/attention.py and models/rglru.py call these;
on CPU they run in interpret mode (kernel body executed in Python — the
TPU lowering is exercised by .lower() in the dry-run)."""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    return _flash(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                  softcap=softcap, block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("block_t", "block_w"))
def rglru_scan(log_a, b, *, block_t: int = 256, block_w: int = 512):
    return _rglru(log_a, b, block_t=block_t, block_w=block_w)

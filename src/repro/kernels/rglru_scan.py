"""RG-LRU linear-recurrence Pallas TPU kernel (target: v5e VPU; validated
with interpret=True on CPU).

h_t = exp(log_a_t) * h_{t-1} + b_t, elementwise over the recurrence width.

Blocking: grid (batch, width_block, time_block) with time sequential
("arbitrary") — the running state h lives in VMEM scratch across time
blocks; within a block the recurrence steps through the [block_t, block_w]
VMEM tile with a fori_loop (VPU elementwise ops, no MXU involvement).
Width blocks are independent -> "parallel", which is what makes the kernel
shard cleanly when the width axis is tensor-sharded over the mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(la_ref, b_ref, o_ref, h_ref, *, block_t: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = la_ref[...]                         # [bt, bw] fp32
    bb = b_ref[...]

    def body(i, h):
        h = jnp.exp(la[i]) * h + bb[i]
        o_ref[pl.ds(i, 1), :] = h[None, :]
        return h

    h_ref[...] = jax.lax.fori_loop(0, block_t, body, h_ref[...])


def rglru_scan(log_a, b, *, block_t: int = 256, block_w: int = 512,
               interpret: Optional[bool] = None):
    """log_a, b: [B, S, W] fp32 -> h: [B, S, W] fp32 (h_0 prior = 0)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, W = log_a.shape
    block_t = min(block_t, S)
    block_w = min(block_w, W)
    pad_t = (-S) % block_t
    pad_w = (-W) % block_w
    if pad_t or pad_w:
        # padded time steps: log_a = 0 (a=1), b = 0 -> state passes through
        log_a = jnp.pad(log_a, ((0, 0), (0, pad_t), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_w)))
    Sp, Wp = S + pad_t, W + pad_w
    grid = (B, Wp // block_w, Sp // block_t)

    out = pl.pallas_call(
        functools.partial(_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_t, block_w), lambda b_, w, t: (b_, t, w)),
            pl.BlockSpec((None, block_t, block_w), lambda b_, w, t: (b_, t, w)),
        ],
        out_specs=pl.BlockSpec((None, block_t, block_w),
                               lambda b_, w, t: (b_, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Wp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(log_a, b)
    return out[:, :S, :W]

from repro.checkpoint.io import (read_manifest, restore_into, restore_tree,
                                 save_tree)

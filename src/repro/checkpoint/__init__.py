from repro.checkpoint.io import save_tree, restore_tree, restore_into

"""Checkpointing: pytree <-> .npz with path-string keys + a JSON manifest.

`save_tree` stores every leaf under its tree path ("params/groups/0/attn/wq")
so checkpoints are inspectable with plain numpy. `restore_into` reloads into
a template pytree (shape/dtype checked); `restore_tree` reloads standalone
(dicts/lists/tuples reconstructed from the manifest).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_tree(path: str, tree: Any, metadata: dict | None = None) -> None:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for p, leaf in leaves_with_paths:
        k = _path_str(p) or "leaf"
        keys.append(k)
        arrays[k] = np.asarray(leaf)
    manifest = {"keys": keys, "treedef": str(treedef),
                "structure": _structure_of(tree),
                "metadata": metadata or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, __manifest__=json.dumps(manifest), **arrays)


def _structure_of(tree) -> Any:
    """JSON-serializable skeleton: leaves -> None."""
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure_of(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": type(tree).__name__,
                "items": [_structure_of(v) for v in tree]}
    return None


def _fill(skel, leaves_iter):
    if skel is None:
        return next(leaves_iter)
    if skel["__kind__"] == "dict":
        return {k: _fill(v, leaves_iter) for k, v in skel["items"].items()}
    items = [_fill(v, leaves_iter) for v in skel["items"]]
    return items if skel["__kind__"] == "list" else tuple(items)


def restore_tree(path: str) -> Any:
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    leaves = [data[k] for k in manifest["keys"]]
    return _fill(manifest["structure"], iter(leaves))


def restore_into(template: Any, path: str) -> Any:
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    leaves = [data[k] for k in manifest["keys"]]
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(f"leaf count mismatch: template {len(t_leaves)} "
                         f"vs checkpoint {len(leaves)}")
    for t, l in zip(t_leaves, leaves):
        if tuple(t.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {t.shape} vs {l.shape}")
    return jax.tree_util.tree_unflatten(treedef, leaves)

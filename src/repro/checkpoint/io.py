"""Checkpointing: pytree <-> .npz with path-string keys + a JSON manifest.

`save_tree` stores every leaf under its tree path ("params/groups/0/attn/wq")
so checkpoints are inspectable with plain numpy. Writes are ATOMIC: the
archive is assembled in a same-directory temp file and `os.replace`d into
place, so a crash (or fault injection) mid-write can never corrupt an
existing resume point — the old checkpoint stays readable
(tests/test_property.py pins this with a simulated partial write).

`restore_into` reloads into a template pytree (shape/dtype checked);
`restore_tree` reloads standalone (dicts/lists/tuples reconstructed from the
manifest); `read_manifest` returns just the manifest (keys, structure,
metadata) without materializing any arrays — resume logic uses it to
validate a checkpoint's config fingerprint before loading.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _json_default(o):
    """Manifest metadata arrives from config payloads that may carry numpy
    scalars (a np.float64 knob, an int64 round index); json.dumps would
    otherwise raise TypeError deep inside the atomic write."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.bool_):
        return bool(o)
    raise TypeError(f"manifest metadata is not JSON-serializable: "
                    f"{type(o).__name__}")


def save_tree(path: str, tree: Any, metadata: dict | None = None) -> str:
    """Atomically write `tree` to `path` (.npz appended if missing, matching
    np.savez). Returns the final path."""
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    keys = []
    for p, leaf in leaves_with_paths:
        k = _path_str(p) or "leaf"
        keys.append(k)
        arrays[k] = np.asarray(leaf)
    manifest = {"keys": keys, "treedef": str(treedef),
                "structure": _structure_of(tree),
                "metadata": metadata or {}}
    if not path.endswith(".npz"):
        path += ".npz"                    # np.savez's own suffix behavior
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    # temp file in the SAME directory so os.replace is an atomic rename
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __manifest__=json.dumps(
                manifest, default=_json_default), **arrays)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):           # only on failure: replace consumed it
            os.unlink(tmp)
    return path


def _structure_of(tree) -> Any:
    """JSON-serializable skeleton: leaves -> None. Dict items are recorded
    in SORTED key order to match jax's tree_flatten ordering — with
    insertion order a dict whose keys weren't inserted sorted would restore
    its leaves scrambled (`_fill` walks the skeleton in the order written
    here while the saved leaves follow jax's sorted flatten)."""
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _structure_of(tree[k])
                          for k in sorted(tree.keys())}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": type(tree).__name__,
                "items": [_structure_of(v) for v in tree]}
    return None


def _fill(skel, leaves_iter):
    if skel is None:
        return next(leaves_iter)
    if skel["__kind__"] == "dict":
        return {k: _fill(v, leaves_iter) for k, v in skel["items"].items()}
    items = [_fill(v, leaves_iter) for v in skel["items"]]
    return items if skel["__kind__"] == "list" else tuple(items)


def read_manifest(path: str) -> dict:
    """The checkpoint's manifest (keys, structure skeleton, metadata) without
    loading any array payloads."""
    with np.load(path, allow_pickle=False) as data:
        return json.loads(str(data["__manifest__"]))


def restore_tree(path: str) -> Any:
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(str(data["__manifest__"]))
        leaves = [data[k] for k in manifest["keys"]]
    return _fill(manifest["structure"], iter(leaves))


def restore_into(template: Any, path: str) -> Any:
    with np.load(path, allow_pickle=False) as data:
        manifest = json.loads(str(data["__manifest__"]))
        leaves = [data[k] for k in manifest["keys"]]
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(f"leaf count mismatch: template {len(t_leaves)} "
                         f"vs checkpoint {len(leaves)}")
    for t, l in zip(t_leaves, leaves):
        if tuple(t.shape) != tuple(l.shape):
            raise ValueError(f"shape mismatch {t.shape} vs {l.shape}")
    return jax.tree_util.tree_unflatten(treedef, leaves)

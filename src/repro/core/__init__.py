"""GenFV core — the paper's contribution (Sec. III-V):

emd          EMD heterogeneity metric + weighted policy (eq. 3-4)
convergence  Theorem 1 bound
mobility     traffic-flow model, V2R holding time (eq. 24-27)
channel      OFDMA uplink rate/delay/energy (eq. 9-11)
gpu_model    GPU latency/power/energy (eq. 6-8)
selection    SUBP1 + the four baseline selection policies
bandwidth    SUBP2 Lagrange/KKT (Algorithm 1)
power        SUBP3 SCA (Algorithm 2)
generation   SUBP4 closed form (eq. 48)
planner      batched/jitted XLA SUBP2-4 kernel + vmapped multi-fleet API
two_scale    Algorithm 3 joint BCD loop -> RoundPlan
"""
from repro.core import emd  # noqa: F401  (module; the emd() fn lives inside)
from repro.core.emd import (aggregate, data_weights, emd_many, kappas,
                            label_histogram, mean_emd)
from repro.core.planner import bucket_size
from repro.core.two_scale import RoundPlan, plan_round, plan_rounds_batched

"""Batched XLA two-scale planner — jitted SUBP2-4 BCD with vmapped
multi-fleet planning.

The numpy reference in `core/{bandwidth,power,generation,two_scale}.py`
walks Algorithm 1 (subgradient bandwidth), Algorithm 2 (SCA power) and the
Algorithm 3 BCD outer loop on the host: up to `bcd_max_iter x (bw_max_iter
+ sca_max_iter)` tiny numpy calls per round, per strategy, per seed. This
module ports the whole small-computation scale to ONE jitted XLA program:

* every loop is a `lax.while_loop` with the SAME iteration structure and
  float-op order as the numpy solvers, run in float64 (`enable_x64`), so
  the results agree to tight tolerances (tests/test_planner.py pins them);
* the selected set is padded into the power-of-two bucket scheme shared
  with `fl/fleet.py` (`bucket_size`, floor 4): padded slots carry zero
  subcarriers / False validity masks and provably cannot perturb the
  result, and jit compiles once per bucket instead of once per distinct K;
* every while-loop carry is **done-guarded** — once a lane converges its
  state freezes — which is what makes `jax.vmap` over independent fleets
  exact: a vmapped `while_loop` keeps stepping all lanes until the slowest
  converges, and the guards make the extra steps no-ops, so
  `plan_rounds_batched` is bitwise-identical to planning each fleet alone.

`two_scale.plan_round(planner="jax")` dispatches here; `planner="numpy"`
keeps the host reference. Design notes: DESIGN.md §"Batched XLA planner".
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import List, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.configs.base import GenFVConfig
from repro.core import channel, gpu_model
from repro.core.generation import DiffusionService
from repro.core.gpu_model import CONSTS, RSU_F_CORE, RSU_SPEEDUP
from repro.core.mobility import Vehicle, rsu_distances
from repro.core.selection import SelectionResult, select

LN2 = float(np.log(2.0))


# ---------------------------------------------------------------------------
# Fleet-size bucketing (shared with fl/fleet.py, which re-exports it).
# ---------------------------------------------------------------------------
def bucket_size(k: int, min_bucket: int = 4, max_bucket: int = 4096) -> int:
    """Smallest power-of-two >= k (clamped to [min_bucket, max_bucket]).

    The floor is 4: XLA:CPU's conv kernels switch strategy at very small
    batch sizes, so a K=2 fleet executed in bucket 2 drifts ~1 ULP from the
    same fleet in bucket 8, while the bucket family {4, 8, 16, ...} is
    bitwise-consistent (tests/test_fleet.py). Padding 1-3 vehicles up to 4
    costs negligible throwaway compute.
    """
    if k > max_bucket:
        raise ValueError(f"fleet of {k} exceeds max bucket {max_bucket}")
    b = max(int(min_bucket), 1)
    while b < k:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# Round plan (moved here from two_scale.py; two_scale re-exports it).
# ---------------------------------------------------------------------------
@dataclass
class RoundPlan:
    alpha: np.ndarray                 # [N] selection indicator
    selected: List[int]               # indices with alpha=1
    l: np.ndarray                     # [K] subcarriers per selected vehicle
    phi: np.ndarray                   # [K] tx power per selected vehicle
    b_gen: int                        # images to generate (SUBP4)
    t_cp: np.ndarray                  # [K] per-vehicle training delay
    t_mu: np.ndarray                  # [K] per-vehicle upload delay
    t_bar: float                      # max_n (t_cp + t_mu) — system delay
    e_total: np.ndarray               # [K] per-vehicle energy
    t_rsu: float                      # RSU generation + augmentation time
    bcd_iters: int = 0
    # BCD stopped before its iteration cap. Host-side definition shared by
    # BOTH backends (`bcd_iters < max_bcd`, conservative when convergence
    # lands exactly on the final allowed iteration) so neither jitted
    # program changes shape; surfaced into RoundLog by fl/rounds.py.
    converged: bool = True
    history: List[float] = field(default_factory=list)   # T_bar per BCD iter
    selection: SelectionResult | None = None


def empty_plan(alpha: np.ndarray,
               sel: SelectionResult | None = None) -> RoundPlan:
    """The no-vehicle-selected plan (shared by both planner backends)."""
    return RoundPlan(alpha, [], np.zeros(0), np.zeros(0), 0,
                     np.zeros(0), np.zeros(0), 0.0, np.zeros(0), 0.0,
                     selection=sel)


# ---------------------------------------------------------------------------
# Per-selected-vehicle constants (shared by the numpy and jax backends).
# ---------------------------------------------------------------------------
class SelectedConsts(NamedTuple):
    t_cp: np.ndarray       # [K] eq. 6 training delay (A in Alg. 1)
    e_cp: np.ndarray       # [K] eq. 8 training energy (C in Alg. 1 / G)
    b_prime: np.ndarray    # [K] shadowed channel gain over noise
    phi_max: np.ndarray    # [K] per-vehicle power cap


def selected_consts(cfg: GenFVConfig, fleet: Sequence[Vehicle],
                    idx: Sequence[int], batches: int) -> SelectedConsts:
    """Constants of the BCD given a selected index set (hoisted out of the
    iteration: they do not change across SUBP2/3/4 passes)."""
    xs = np.array([fleet[i].x for i in idx], np.float64)
    f_mem = np.array([fleet[i].f_mem for i in idx], np.float64)
    f_core = np.array([fleet[i].f_core for i in idx], np.float64)
    v_core = np.array([fleet[i].v_core for i in idx], np.float64)
    gain_db = np.array([fleet[i].gain_db for i in idx], np.float64)
    phi_max = np.array([fleet[i].phi_max for i in idx], np.float64)

    dists = rsu_distances(cfg, xs)
    t_cp = gpu_model.train_times(f_mem, f_core, batches)
    e_cp = gpu_model.runtime_powers(f_mem, f_core, v_core) * t_cp
    n0 = channel.noise_watts(cfg)
    # per-vehicle shadowed channel gain (legacy fleets carry gain_db=0,
    # where the 10^(0/10)=1.0 multiplier reproduces the unshadowed value
    # bitwise)
    shadow = channel.shadow_linear(gain_db)
    b_prime = (cfg.unit_channel_gain * shadow
               * dists ** (-cfg.path_loss_exp) / n0)
    return SelectedConsts(t_cp, e_cp, b_prime, phi_max)


# ---------------------------------------------------------------------------
# Kernel constants: traced scalars, so one compilation per (bucket, max_bcd)
# serves every GenFVConfig.
# ---------------------------------------------------------------------------
class PlannerConsts(NamedTuple):
    model_bits: float
    M: float               # num_subcarriers
    W: float               # subcarrier_bw
    e_bar: float           # e_max
    phi_min: float
    t_max: float
    l_min: float
    bw_step: float
    bw_tol: float
    bw_max_iter: int
    sca_eps: float
    sca_max_iter: int
    bcd_eps: float
    gen_batch: int
    t_per_image: float     # eq. 12 t0
    g_t0: float            # rsu_train_time pieces (eq. 13)
    g_c1: float
    g_theta_mem: float
    g_c2: float
    g_theta_core: float
    rsu_denom: float       # 1.5e9 * speedup


def planner_consts(cfg: GenFVConfig, model_bits: float,
                   svc: DiffusionService, eps: float) -> PlannerConsts:
    g = CONSTS
    return PlannerConsts(
        model_bits=float(model_bits), M=float(cfg.num_subcarriers),
        W=float(cfg.subcarrier_bw), e_bar=float(cfg.e_max),
        phi_min=float(cfg.phi_min), t_max=float(cfg.t_max),
        l_min=float(cfg.bw_l_min), bw_step=float(cfg.bw_step),
        bw_tol=float(cfg.bw_tol), bw_max_iter=int(cfg.bw_max_iter),
        sca_eps=float(cfg.sca_eps), sca_max_iter=int(cfg.sca_max_iter),
        bcd_eps=float(eps), gen_batch=int(cfg.gen_batch),
        t_per_image=float(svc.t_per_image),
        g_t0=float(g.t0), g_c1=float(g.c1), g_theta_mem=float(g.theta_mem),
        g_c2=float(g.c2), g_theta_core=float(g.theta_core),
        rsu_denom=float(RSU_F_CORE * RSU_SPEEDUP))


@lru_cache(maxsize=64)
def _device_consts(c: PlannerConsts) -> PlannerConsts:
    """Device-resident copy of the consts: uploading 21 host scalars per
    dispatch costs ~0.1 ms on CPU, and the runner calls the planner with
    the same config every round."""
    with enable_x64():
        return PlannerConsts(*(jnp.asarray(v) for v in c))


# ---------------------------------------------------------------------------
# The kernel: one fleet, padded arrays [Kp], valid mask. All loops mirror
# the numpy solvers' iteration structure and float-op order exactly.
# ---------------------------------------------------------------------------
def _project_budget(l, c: PlannerConsts, valid):
    """bandwidth.project_budget with masked padding (pads hold l=0)."""
    kp = l.shape[0]

    def body(st):
        l, pinned, done, i = st
        free = valid & ~pinned
        s_pin = c.l_min * jnp.sum((valid & pinned).astype(l.dtype))
        s_free = jnp.sum(jnp.where(free, l, 0.0))
        need = s_pin + s_free > c.M
        scale = jnp.maximum(c.M - s_pin, 0.0) / jnp.maximum(s_free, 1e-300)
        l_sc = jnp.where(free, l * scale, jnp.where(valid, c.l_min, 0.0))
        newly = free & (l_sc < c.l_min)
        l_new = jnp.where(newly, c.l_min, l_sc)
        l_out = jnp.where(done | ~need, l, l_new)
        pinned_out = jnp.where(done | ~need, pinned, pinned | newly)
        done_out = done | ~need | ~jnp.any(newly)
        return l_out, pinned_out, done_out, i + 1

    def cond(st):
        return ~st[2] & (st[3] < kp)

    l, _, _, _ = lax.while_loop(cond, body,
                                (l, jnp.zeros(kp, bool), False, 0))
    return l


def _solve_bandwidth(c: PlannerConsts, B, D, t_cp, e_cp, valid, n_val):
    """Algorithm 1 (eq. 33-38): projected subgradient ascent on the
    multipliers, done-guarded for vmap-exactness."""
    l0 = jnp.where(valid, c.M / n_val, 0.0)

    def body(st):
        lam1, lam2, lam3, l, prev, it, done = st
        l_n = jnp.sqrt((lam1 * B + lam2 * D) / jnp.maximum(lam3, 1e-9))
        l_n = jnp.where(valid, jnp.clip(l_n, c.l_min, c.M), 0.0)
        l_n = _project_budget(l_n, c, valid)
        l_safe = jnp.where(valid, l_n, 1.0)
        delay = jnp.where(valid, t_cp + B / l_safe, -jnp.inf)
        t_bar = jnp.max(delay)
        g1 = jnp.where(valid, delay - t_bar, 0.0)
        g2 = jnp.sum(jnp.where(valid, e_cp + D / l_safe, 0.0)) \
            - c.e_bar * n_val
        g3 = jnp.sum(l_n) - c.M
        lam1_n = jnp.maximum(lam1 + c.bw_step * g1, 0.0) + 1e-12
        lam2_n = jnp.maximum(lam2 + c.bw_step * g2, 0.0) + 1e-12
        lam3_n = jnp.maximum(lam3 + c.bw_step * g3, 1e-6)
        conv = jnp.max(jnp.abs(l_n - prev)) < c.bw_tol
        it_n = it + 1
        keep = lambda old, new: jnp.where(done, old, new)   # noqa: E731
        return (keep(lam1, lam1_n), keep(lam2, lam2_n), keep(lam3, lam3_n),
                keep(l, l_n), keep(prev, l_n), keep(it, it_n),
                done | conv | (it_n >= c.bw_max_iter))

    st = (jnp.ones_like(l0), 1.0, 1.0, l0, l0, 0, False)
    st = lax.while_loop(lambda s: ~s[6], body, st)
    return st[3]


def _solve_power(c: PlannerConsts, l_w, b_prime, e_cp, phi_max, valid):
    """Algorithm 2 (eq. 39-46): SCA fixed point, done-guarded."""
    lw_s = jnp.where(valid, l_w, 1.0)
    bp_s = jnp.where(valid, b_prime, 1.0)
    a = c.model_bits / lw_s

    def body(st):
        phi, it, done = st
        u = bp_s * phi
        log2u = jnp.log2(1.0 + u)
        e_i = phi * (c.model_bits / (lw_s * log2u))
        de = a / log2u - a * bp_s * phi / (LN2 * (1.0 + u) * log2u ** 2)
        slack = c.e_bar - e_cp - e_i
        phi_b = jnp.where(de > 1e-12, phi + slack / de, phi_max)
        phi_n = jnp.clip(jnp.minimum(phi_b, phi_max), c.phi_min, phi_max)
        conv = jnp.max(jnp.where(valid, jnp.abs(phi_n - phi), 0.0)) \
            < c.sca_eps
        it_n = it + 1
        return (jnp.where(done, phi, phi_n), jnp.where(done, it, it_n),
                done | conv | (it_n >= c.sca_max_iter))

    st = (jnp.full_like(l_w, c.phi_min), 0, False)
    st = lax.while_loop(lambda s: ~s[2], body, st)
    return st[0]


def _rsu_train_time(c: PlannerConsts, bt):
    """Eq. 13 (gpu_model.rsu_train_time) for bt augmented batches."""
    return c.g_t0 + (c.g_c1 * bt * c.g_theta_mem
                     + c.g_c2 * bt * c.g_theta_core) / c.rsu_denom


def _optimal_generation(c: PlannerConsts, t_bar, b_prev):
    """Eq. 48 closed form (generation.optimal_generation)."""
    bt = jnp.maximum(b_prev // c.gen_batch, 1).astype(t_bar.dtype)
    budget = jnp.minimum(t_bar, c.t_max) - _rsu_train_time(c, bt)
    return jnp.where(budget > 0.0,
                     jnp.floor(budget / c.t_per_image),
                     0.0).astype(b_prev.dtype)


def _bcd_kernel(c: PlannerConsts, t_cp, e_cp, b_prime, phi_max, valid,
                b_prev, max_bcd: int):
    """Algorithm 3 small-computation scale for one (padded) fleet."""
    n_val = jnp.sum(valid.astype(t_cp.dtype))
    bp_s = jnp.where(valid, b_prime, 1.0)

    def t_mu_of(l, phi):
        lw_s = jnp.where(valid, l * c.W, 1.0)
        return c.model_bits / (lw_s * jnp.log2(1.0 + bp_s * phi))

    def body(st):
        l, phi, b, it, done, hist = st
        # SUBP2: bandwidth given phi, b
        rate1 = c.W * jnp.log2(1.0 + bp_s * phi)
        B = jnp.where(valid, c.model_bits / rate1, 0.0)
        D = jnp.where(valid, phi * B, 0.0)
        l_n = _solve_bandwidth(c, B, D, t_cp, e_cp, valid, n_val)
        # SUBP3: power given l, b
        phi_n = _solve_power(c, l_n * c.W, b_prime, e_cp, phi_max, valid)
        # SUBP4: generation given l, phi (closed form, eq. 48)
        t_mu = t_mu_of(l_n, phi_n)
        t_bar = jnp.max(jnp.where(valid, t_cp + t_mu, -jnp.inf))
        b_n = _optimal_generation(c, t_bar, b)
        hist_n = lax.dynamic_update_index_in_dim(hist, t_bar, it, 0)
        conv = ((jnp.max(jnp.where(valid, jnp.abs(l_n - l), 0.0)) < c.bcd_eps)
                & (jnp.max(jnp.where(valid, jnp.abs(phi_n - phi), 0.0))
                   < c.bcd_eps)
                & (jnp.abs(b_n - b) < 1))
        it_n = it + 1
        keep = lambda old, new: jnp.where(done, old, new)   # noqa: E731
        return (keep(l, l_n), keep(phi, phi_n), keep(b, b_n),
                keep(it, it_n), done | conv | (it_n >= max_bcd),
                keep(hist, hist_n))

    l0 = jnp.where(valid, c.M / n_val, 0.0)
    phi0 = jnp.where(valid, phi_max, 0.0)
    b0 = jnp.asarray(b_prev, jnp.int64 if jax.config.jax_enable_x64
                     else jnp.int32)
    st = (l0, phi0, b0, 0, max_bcd <= 0,
          jnp.zeros(max_bcd if max_bcd > 0 else 1, t_cp.dtype))
    l, phi, b, it, _, hist = lax.while_loop(lambda s: ~s[4], body, st)

    # final ledger (mirrors the tail of the numpy plan_round)
    t_mu = jnp.where(valid, t_mu_of(l, phi), 0.0)
    e_mu = phi * t_mu
    t_bar = jnp.max(jnp.where(valid, t_cp + t_mu, -jnp.inf))
    bt = jnp.maximum(b // c.gen_batch, 1).astype(t_cp.dtype)
    t_rsu = (b.astype(t_cp.dtype) * c.t_per_image
             + _rsu_train_time(c, bt))
    return l, phi, b, t_mu, e_mu, t_bar, t_rsu, it, hist


_plan_one = partial(jax.jit, static_argnums=(7,))(_bcd_kernel)


@partial(jax.jit, static_argnums=(7,))
def _plan_many(c, t_cp, e_cp, b_prime, phi_max, valid, b_prev, max_bcd):
    """vmap over a leading fleet axis; consts broadcast."""
    return jax.vmap(
        lambda a, e, bp, pm, v, b: _bcd_kernel(c, a, e, bp, pm, v, b,
                                               max_bcd)
    )(t_cp, e_cp, b_prime, phi_max, valid, b_prev)


# ---------------------------------------------------------------------------
# Host-side wrappers: pad to bucket, dispatch under x64, unpack.
# ---------------------------------------------------------------------------
def _pad(x: np.ndarray, kp: int, fill: float = 0.0) -> np.ndarray:
    x = np.asarray(x, np.float64)
    if len(x) == kp:
        return x
    return np.concatenate([x, np.full(kp - len(x), fill)])


def plan_selected_jax(cfg: GenFVConfig, model_bits: float,
                      consts: SelectedConsts, b_prev: int,
                      svc: DiffusionService, eps: float,
                      max_bcd: int, bucket: int | None = None) -> dict:
    """Run the jitted BCD for one already-selected fleet. Returns the raw
    ledger arrays (trimmed to K) for RoundPlan assembly. `bucket` overrides
    the power-of-two padding (tests use it to prove pad-invariance)."""
    k = len(consts.t_cp)
    kp = bucket_size(k) if bucket is None else int(bucket)
    if kp < k:
        raise ValueError(f"bucket {kp} smaller than fleet {k}")
    valid = np.zeros(kp, bool)
    valid[:k] = True
    c = _device_consts(planner_consts(cfg, model_bits, svc, eps))
    with enable_x64():
        out = _plan_one(c, _pad(consts.t_cp, kp), _pad(consts.e_cp, kp),
                        _pad(consts.b_prime, kp),
                        _pad(consts.phi_max, kp, cfg.phi_min),
                        jnp.asarray(valid), int(b_prev), int(max_bcd))
        out = [np.asarray(o) for o in out]
    return _unpack(out, k, int(max_bcd))


def _unpack(out, k: int, max_bcd: int) -> dict:
    l, phi, b, t_mu, e_mu, t_bar, t_rsu, it, hist = out
    iters = int(it)
    return dict(l=l[:k], phi=phi[:k], b_gen=int(b), t_mu=t_mu[:k],
                e_mu=e_mu[:k], t_bar=float(t_bar), t_rsu=float(t_rsu),
                bcd_iters=iters, converged=iters < max_bcd,
                history=[float(h) for h in hist[:iters]])


def plan_rounds_batched(cfg: GenFVConfig, fleets: Sequence[Sequence[Vehicle]],
                        model_bits: float, batches: int,
                        b_prevs: Sequence[int] | None = None,
                        alpha_overrides: Sequence[np.ndarray | None] | None
                        = None,
                        svc: DiffusionService | None = None,
                        eps: float | None = None,
                        max_bcd: int | None = None) -> List[RoundPlan]:
    """Plan many independent fleets in ONE vmapped dispatch.

    Fleets may differ in size and selected-set size; all selected sets are
    padded to a common power-of-two bucket. Per-fleet results are
    bitwise-identical to calling `plan_round(..., planner="jax")` fleet by
    fleet (the done-guarded loops freeze converged lanes). Intended for
    baseline sweeps: strategies x seeds x scenarios with a shared config.
    """
    svc = svc or DiffusionService(steps=cfg.diffusion_steps)
    eps = cfg.bcd_eps if eps is None else eps
    max_bcd = cfg.bcd_max_iter if max_bcd is None else max_bcd
    n_fleet = len(fleets)
    b_prevs = [0] * n_fleet if b_prevs is None else list(b_prevs)
    overrides = ([None] * n_fleet if alpha_overrides is None
                 else list(alpha_overrides))

    sels, alphas, idxs, consts = [], [], [], []
    for fleet, ov in zip(fleets, overrides):
        if ov is None:
            sel = select(cfg, fleet, model_bits, batches)
            alpha = sel.alpha
        else:
            sel = None
            alpha = np.asarray(ov)
        idx = [i for i in range(len(fleet)) if alpha[i] == 1]
        sels.append(sel)
        alphas.append(alpha)
        idxs.append(idx)
        consts.append(selected_consts(cfg, fleet, idx, batches))

    live = [f for f in range(n_fleet) if idxs[f]]
    plans: List[RoundPlan | None] = [None] * n_fleet
    for f in range(n_fleet):
        if f not in live:
            plans[f] = empty_plan(alphas[f], sels[f])
    if not live:
        return plans

    kp = bucket_size(max(len(idxs[f]) for f in live))
    c = _device_consts(planner_consts(cfg, model_bits, svc, eps))
    stack = lambda g, fill=0.0: np.stack(                   # noqa: E731
        [_pad(g(consts[f]), kp, fill) for f in live])
    valid = np.zeros((len(live), kp), bool)
    for row, f in enumerate(live):
        valid[row, :len(idxs[f])] = True
    with enable_x64():
        out = _plan_many(c, stack(lambda s: s.t_cp), stack(lambda s: s.e_cp),
                         stack(lambda s: s.b_prime),
                         stack(lambda s: s.phi_max, cfg.phi_min),
                         jnp.asarray(valid),
                         np.asarray([b_prevs[f] for f in live], np.int64),
                         int(max_bcd))
        out = [np.asarray(o) for o in out]
    for row, f in enumerate(live):
        r = _unpack([o[row] for o in out], len(idxs[f]), int(max_bcd))
        s = consts[f]
        plans[f] = RoundPlan(
            alpha=alphas[f], selected=idxs[f], l=r["l"], phi=r["phi"],
            b_gen=r["b_gen"], t_cp=s.t_cp, t_mu=r["t_mu"],
            t_bar=r["t_bar"], e_total=s.e_cp + r["e_mu"], t_rsu=r["t_rsu"],
            bcd_iters=r["bcd_iters"], converged=r["converged"],
            history=r["history"], selection=sels[f])
    return plans

"""SUBP3 — transmission-power assignment by Successive Convex Approximation
(paper Sec. V-B3, eq. 39-46, Algorithm 2).

Non-convex terms:
    t(phi) = s(w) / (l W log2(1 + B' phi))        (upload delay)
    e(phi) = phi t(phi)                            (upload energy)
are replaced by first-order Taylor expansions around phi^i each iteration;
the resulting convex subproblem has the closed form: push phi up (delay
decreases monotonically) until the linearized energy budget or phi_max
binds. Iterate to a fixed point (Algorithm 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class PowerResult:
    phi: np.ndarray
    t_bar: float
    iters: int
    converged: bool


def t_of_phi(s_bits: float, l_w: np.ndarray, b_prime: np.ndarray,
             phi: np.ndarray) -> np.ndarray:
    """Eq. (41): upload delay; l_w = l_n * W (allocated bandwidth, Hz)."""
    return s_bits / (l_w * np.log2(1.0 + b_prime * phi))


def t_prime(s_bits: float, l_w: np.ndarray, b_prime: np.ndarray,
            phi: np.ndarray) -> np.ndarray:
    """Eq. (43): dt/dphi (negative)."""
    a = s_bits / l_w
    u = b_prime * phi
    return -a * b_prime * np.log(2.0) / ((1.0 + u) * np.log(1.0 + u) ** 2)


def e_of_phi(s_bits: float, l_w, b_prime, phi) -> np.ndarray:
    """Eq. (44)."""
    return phi * t_of_phi(s_bits, l_w, b_prime, phi)


def e_prime(s_bits: float, l_w, b_prime, phi) -> np.ndarray:
    """Eq. (46): de/dphi."""
    a = s_bits / l_w
    u = b_prime * phi
    log2u = np.log2(1.0 + u)
    return a / log2u - a * b_prime * phi / (np.log(2.0) * (1.0 + u) * log2u ** 2)


def solve_power(s_bits: float, l_w: np.ndarray, b_prime: np.ndarray,
                G: np.ndarray, e_bar: float, phi_min: float, phi_max,
                max_iter: int = 50, eps: float = 1e-4) -> PowerResult:
    """Algorithm 2. G: non-transmission energy (training); per-vehicle
    budget: G + e(phi) <= e_bar. phi_max may be scalar or per-vehicle."""
    n = l_w.shape[0]
    if n == 0:
        return PowerResult(np.zeros(0), 0.0, 0, True)
    phi_max = np.broadcast_to(np.asarray(phi_max, np.float64), (n,))
    phi = np.full(n, phi_min, np.float64)
    it = 0
    converged = False   # explicit: a fixed point reached exactly on the
    for it in range(1, max_iter + 1):   # last iteration still counts
        e_i = e_of_phi(s_bits, l_w, b_prime, phi)
        de = e_prime(s_bits, l_w, b_prime, phi)
        # linearized budget: G + e_i + de*(phi_new - phi) <= e_bar
        slack = e_bar - G - e_i
        with np.errstate(divide="ignore", invalid="ignore"):
            phi_budget = np.where(de > 1e-12, phi + slack / de, phi_max)
        phi_new = np.clip(np.minimum(phi_budget, phi_max), phi_min, phi_max)
        if np.max(np.abs(phi_new - phi)) < eps:
            phi = phi_new
            converged = True
            break
        phi = phi_new
    t_bar = float(np.max(t_of_phi(s_bits, l_w, b_prime, phi)))
    return PowerResult(phi, t_bar, it, converged)

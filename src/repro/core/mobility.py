"""Vehicle mobility model (paper Sec. V-A2, eq. 24-27, Fig. 3).

Vehicles arrive as a Poisson process; average speed depends on road load
(eq. 24); individual speeds are truncated-normal around the average; the V2R
holding time is the remaining in-coverage distance over speed (eq. 25-26).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import GenFVConfig


@dataclass
class Vehicle:
    vid: int
    x: float              # signed position along the road, 0 = RSU foot (m)
    v: float              # signed velocity (m/s); sign = direction
    phi_max: float        # max uplink tx power (W)
    f_mem: float          # GPU memory frequency (Hz)
    f_core: float         # GPU core frequency (Hz)
    v_core: float         # GPU core voltage (V)
    data_size: int        # |D_n|
    hist: np.ndarray      # label histogram p_n(y)
    emd: float            # EMD_n
    gain_db: float = 0.0  # slow-fading shadowing offset on h0 (dB; sim layer)


def average_speed(cfg: GenFVConfig, m_on_road: int) -> float:
    """Eq. (24): v_bar = max(v_max (1 - M/M_max), v_min), in km/h."""
    return max(cfg.v_max * (1.0 - m_on_road / cfg.m_max), cfg.v_min)


def sample_speeds(rng: np.random.Generator, cfg: GenFVConfig, n: int,
                  m_on_road: int) -> np.ndarray:
    """Truncated-normal speeds (km/h): sigma = k v_bar, floor at v_min."""
    v_bar = average_speed(cfg, m_on_road)
    sigma = cfg.sigma_k * v_bar
    v = rng.normal(v_bar, sigma, size=n)
    return np.clip(v, cfg.v_min, cfg.v_max)


def coverage_half_length(cfg: GenFVConfig) -> float:
    """sqrt(r^2 - e^2): half of the RSU's coverage chord on the road."""
    return float(np.sqrt(cfg.rsu_radius ** 2 - cfg.rsu_road_offset ** 2))


def remaining_distance(cfg: GenFVConfig, x: float, v: float) -> float:
    """Eq. (25): s_n = sqrt(r^2-e^2) - sign(v) * x."""
    half = coverage_half_length(cfg)
    return half - np.sign(v) * x


def holding_time(cfg: GenFVConfig, x: float, v_kmh: float) -> float:
    """Eq. (26): t_hold = s_n / |v_n| (seconds; v in km/h -> m/s)."""
    v_ms = abs(v_kmh) / 3.6
    s = remaining_distance(cfg, x, v_kmh)
    return float(max(s, 0.0) / max(v_ms, 1e-9))


def rsu_distance(cfg: GenFVConfig, x: float) -> float:
    """Euclidean distance vehicle -> RSU (for the path-loss model)."""
    return float(np.hypot(x, cfg.rsu_road_offset))


# ---------------------------------------------------------------------------
# Vectorized variants (repro.sim world stepping / dropout accounting). Same
# math as the scalar functions above, applied elementwise to [N] arrays.
# ---------------------------------------------------------------------------
def remaining_distances(cfg: GenFVConfig, x: np.ndarray,
                        v: np.ndarray) -> np.ndarray:
    """Eq. (25) over arrays: s_n = sqrt(r^2-e^2) - sign(v_n) * x_n."""
    half = coverage_half_length(cfg)
    return half - np.sign(v) * np.asarray(x, np.float64)


def holding_times(cfg: GenFVConfig, x: np.ndarray,
                  v_kmh: np.ndarray) -> np.ndarray:
    """Eq. (26) over arrays: t_hold = max(s_n, 0) / max(|v_n|, eps)."""
    v_ms = np.abs(np.asarray(v_kmh, np.float64)) / 3.6
    s = remaining_distances(cfg, x, v_kmh)
    return np.maximum(s, 0.0) / np.maximum(v_ms, 1e-9)


def rsu_distances(cfg: GenFVConfig, x: np.ndarray) -> np.ndarray:
    """Euclidean vehicle -> RSU distance over an [N] position array."""
    return np.hypot(np.asarray(x, np.float64), cfg.rsu_road_offset)


def sample_fleet(rng: np.random.Generator, cfg: GenFVConfig, hists,
                 sizes) -> list[Vehicle]:
    """Sample the in-range fleet: Poisson count (capped to available data
    partitions), uniform positions on the coverage chord, eq.-24 speeds,
    random GPU/radio capabilities (Sec. VI-A3 ranges)."""
    n_avail = len(sizes)
    draw = rng.poisson(cfg.num_vehicles)
    n = min(max(draw, 1), n_avail)
    half = coverage_half_length(cfg)
    xs = rng.uniform(-half, half, size=n)
    dirs = np.where(rng.random(n) < 0.5, -1.0, 1.0)
    # eq. 24 road load uses the UNCAPPED Poisson draw: capping to the number
    # of available data partitions bounds how many vehicles can be FL clients,
    # but the extra vehicles are still physically on the road and congest it.
    speeds = sample_speeds(rng, cfg, n, m_on_road=max(draw, 1)) * dirs
    fleet = []
    for i in range(n):
        hist = np.asarray(hists[i], np.float64)
        p_glob = np.full_like(hist, 1.0 / hist.shape[0])
        fleet.append(Vehicle(
            vid=i,
            x=float(xs[i]),
            v=float(speeds[i]),
            phi_max=float(rng.uniform(cfg.phi_min, cfg.phi_max)),
            f_mem=float(rng.uniform(1.25e9, 1.75e9)),
            f_core=float(rng.uniform(1.0e9, 1.6e9)),
            v_core=float(rng.uniform(0.8, 1.1)),
            data_size=int(sizes[i]),
            hist=hist,
            emd=float(np.abs(hist - p_glob).sum()),
        ))
    return fleet

"""Algorithm 3 — Joint Two-Scale Algorithm (paper Sec. V-C).

Large communication scale: label sharing + SUBP1 vehicle selection.
Small computation scale:   BCD over SUBP2 (bandwidth) -> SUBP3 (power)
                           -> SUBP4 (generation) until all three deltas
                           fall below the epsilons.

Outputs a `RoundPlan`: who participates, their subcarriers/powers, the
number of images the RSU generates, and the full delay/energy ledger that
the FL runtime uses as the simulated round clock.

Two backends solve the small scale:
  planner="jax"   (default) — the jitted/batched XLA kernel in
                  core/planner.py (lax.while_loop BCD, bucket-padded).
  planner="numpy" — the host reference loop below; it pins the paper math
                  and the equivalence tests (tests/test_planner.py) hold
                  the jax backend to it.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import bandwidth as bw
from repro.core import power as pw
from repro.core.generation import DiffusionService, inference_time, \
    optimal_generation
from repro.core.gpu_model import rsu_train_time
from repro.core.mobility import Vehicle
from repro.core.planner import (RoundPlan, empty_plan, plan_rounds_batched,
                                plan_selected_jax, selected_consts)
from repro.core.selection import select

__all__ = ["RoundPlan", "plan_round", "plan_rounds_batched"]


def plan_round(cfg: GenFVConfig, fleet: List[Vehicle], model_bits: float,
               batches: int, b_prev: int = 0,
               svc: DiffusionService | None = None,
               eps: float | None = None, max_bcd: int | None = None,
               alpha_override: np.ndarray | None = None,
               planner: str = "jax") -> RoundPlan:
    svc = svc or DiffusionService(steps=cfg.diffusion_steps)
    eps = cfg.bcd_eps if eps is None else eps
    max_bcd = cfg.bcd_max_iter if max_bcd is None else max_bcd
    if planner not in ("jax", "numpy"):
        raise ValueError(f"unknown planner {planner!r}")

    # ---- Large communication scale: label share + SUBP1 ------------------
    # With an alpha_override the caller already ran strategy-specific
    # selection (fl/rounds.py), so re-running SUBP1 here would double the
    # selection work per round; plan.selection is None in that case.
    if alpha_override is None:
        sel = select(cfg, fleet, model_bits, batches)
        alpha = sel.alpha
    else:
        sel = None
        alpha = np.asarray(alpha_override)
    idx = [i for i in range(len(fleet)) if alpha[i] == 1]
    if not idx:
        return empty_plan(alpha, sel)

    # ---- constants per selected vehicle (hoisted out of the BCD) ---------
    c = selected_consts(cfg, fleet, idx, batches)

    # ---- Small computation scale: BCD over SUBP2/3/4 ----------------------
    if planner == "jax":
        r = plan_selected_jax(cfg, model_bits, c, b_prev, svc, eps, max_bcd)
        return RoundPlan(alpha=alpha, selected=idx, l=r["l"], phi=r["phi"],
                         b_gen=r["b_gen"], t_cp=c.t_cp, t_mu=r["t_mu"],
                         t_bar=r["t_bar"], e_total=c.e_cp + r["e_mu"],
                         t_rsu=r["t_rsu"], bcd_iters=r["bcd_iters"],
                         converged=r["converged"], history=r["history"],
                         selection=sel)

    K = len(idx)
    t_cp, e_cp, b_prime, phi_max = c.t_cp, c.e_cp, c.b_prime, c.phi_max
    l = bw.equal_share(K, cfg.num_subcarriers)
    phi = phi_max.copy()
    b_gen = b_prev
    history: List[float] = []
    it = 0
    for it in range(1, max_bcd + 1):
        l_old, phi_old, b_old = l.copy(), phi.copy(), b_gen

        # SUBP2: bandwidth given phi, b
        rate_1sub = cfg.subcarrier_bw * np.log2(1.0 + b_prime * phi)
        B = model_bits / rate_1sub                 # T_mu = B / l_n
        D = phi * B                                # E_mu = D / l_n
        res2 = bw.solve_bandwidth(t_cp, B, e_cp, D, cfg.num_subcarriers,
                                  cfg.e_max, l_min=cfg.bw_l_min,
                                  step=cfg.bw_step, max_iter=cfg.bw_max_iter,
                                  tol=cfg.bw_tol)
        l = res2.l

        # SUBP3: power given l, b
        res3 = pw.solve_power(model_bits, l * cfg.subcarrier_bw, b_prime,
                              e_cp, cfg.e_max, cfg.phi_min, phi_max,
                              max_iter=cfg.sca_max_iter, eps=cfg.sca_eps)
        phi = res3.phi

        # SUBP4: generation given l, phi (closed form, eq. 48)
        t_mu = pw.t_of_phi(model_bits, l * cfg.subcarrier_bw, b_prime, phi)
        t_bar = float(np.max(t_cp + t_mu))
        b_gen = optimal_generation(min(t_bar, cfg.t_max), b_old, svc,
                                   cfg.gen_batch)
        history.append(t_bar)

        if (np.max(np.abs(l - l_old)) < eps
                and np.max(np.abs(phi - phi_old)) < eps
                and abs(b_gen - b_old) < 1):
            break

    t_mu = pw.t_of_phi(model_bits, l * cfg.subcarrier_bw, b_prime, phi)
    e_mu = phi * t_mu
    t_bar = float(np.max(t_cp + t_mu))
    t_rsu = inference_time(svc, b_gen) + rsu_train_time(
        max(b_gen // cfg.gen_batch, 1))
    # `it < max_bcd` matches the jax backend's host-side convergence
    # definition (conservative when the break lands on the final iteration)
    return RoundPlan(alpha=alpha, selected=idx, l=l, phi=phi, b_gen=b_gen,
                     t_cp=t_cp, t_mu=t_mu, t_bar=t_bar,
                     e_total=e_cp + e_mu, t_rsu=t_rsu, bcd_iters=it,
                     converged=it < max_bcd, history=history, selection=sel)

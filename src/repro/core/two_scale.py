"""Algorithm 3 — Joint Two-Scale Algorithm (paper Sec. V-C).

Large communication scale: label sharing + SUBP1 vehicle selection.
Small computation scale:   BCD over SUBP2 (bandwidth) -> SUBP3 (power)
                           -> SUBP4 (generation) until all three deltas
                           fall below the epsilons.

Outputs a `RoundPlan`: who participates, their subcarriers/powers, the
number of images the RSU generates, and the full delay/energy ledger that
the FL runtime uses as the simulated round clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import bandwidth as bw
from repro.core import channel, gpu_model, power as pw
from repro.core.generation import DiffusionService, inference_time, optimal_generation
from repro.core.gpu_model import rsu_train_time
from repro.core.mobility import Vehicle, rsu_distances
from repro.core.selection import SelectionResult, select


@dataclass
class RoundPlan:
    alpha: np.ndarray                 # [N] selection indicator
    selected: List[int]               # indices with alpha=1
    l: np.ndarray                     # [K] subcarriers per selected vehicle
    phi: np.ndarray                   # [K] tx power per selected vehicle
    b_gen: int                        # images to generate (SUBP4)
    t_cp: np.ndarray                  # [K] per-vehicle training delay
    t_mu: np.ndarray                  # [K] per-vehicle upload delay
    t_bar: float                      # max_n (t_cp + t_mu) — system delay
    e_total: np.ndarray               # [K] per-vehicle energy
    t_rsu: float                      # RSU generation + augmentation time
    bcd_iters: int = 0
    history: List[float] = field(default_factory=list)   # T_bar per BCD iter
    selection: SelectionResult | None = None


def plan_round(cfg: GenFVConfig, fleet: List[Vehicle], model_bits: float,
               batches: int, b_prev: int = 0,
               svc: DiffusionService | None = None,
               eps: float = 1e-3, max_bcd: int = 20,
               alpha_override: np.ndarray | None = None) -> RoundPlan:
    svc = svc or DiffusionService(steps=cfg.diffusion_steps)

    # ---- Large communication scale: label share + SUBP1 ------------------
    # With an alpha_override the caller already ran strategy-specific
    # selection (fl/rounds.py), so re-running SUBP1 here would double the
    # selection work per round; plan.selection is None in that case.
    if alpha_override is None:
        sel = select(cfg, fleet, model_bits, batches)
        alpha = sel.alpha
    else:
        sel = None
        alpha = np.asarray(alpha_override)
    idx = [i for i in range(len(fleet)) if alpha[i] == 1]
    if not idx:
        return RoundPlan(alpha, [], np.zeros(0), np.zeros(0), 0,
                         np.zeros(0), np.zeros(0), 0.0, np.zeros(0), 0.0,
                         selection=sel)
    sub = [fleet[i] for i in idx]
    K = len(sub)

    # ---- constants per selected vehicle ----------------------------------
    dists = rsu_distances(cfg, np.array([v.x for v in sub]))
    t_cp = np.array([gpu_model.train_time(v, batches) for v in sub])   # A
    p_run = np.array([gpu_model.runtime_power(v) for v in sub])
    e_cp = p_run * t_cp                                                # C (per =G)
    n0 = channel.noise_watts(cfg)
    # per-vehicle shadowed channel gain (legacy fleets carry gain_db=0, where
    # the 10^(0/10)=1.0 multiplier reproduces the unshadowed value bitwise)
    shadow = channel.shadow_linear(np.array([v.gain_db for v in sub]))
    b_prime = (cfg.unit_channel_gain * shadow
               * dists ** (-cfg.path_loss_exp) / n0)

    # ---- Small computation scale: BCD over SUBP2/3/4 ----------------------
    l = bw.equal_share(K, cfg.num_subcarriers)
    phi = np.array([v.phi_max for v in sub])
    b_gen = b_prev
    history: List[float] = []
    it = 0
    for it in range(1, max_bcd + 1):
        l_old, phi_old, b_old = l.copy(), phi.copy(), b_gen

        # SUBP2: bandwidth given phi, b
        rate_1sub = cfg.subcarrier_bw * np.log2(1.0 + b_prime * phi)
        B = model_bits / rate_1sub                 # T_mu = B / l_n
        D = phi * B                                # E_mu = D / l_n
        res2 = bw.solve_bandwidth(t_cp, B, e_cp, D, cfg.num_subcarriers,
                                  cfg.e_max)
        l = res2.l

        # SUBP3: power given l, b
        res3 = pw.solve_power(model_bits, l * cfg.subcarrier_bw, b_prime,
                              e_cp, cfg.e_max, cfg.phi_min,
                              np.array([v.phi_max for v in sub]))
        phi = res3.phi

        # SUBP4: generation given l, phi (closed form, eq. 48)
        t_mu = pw.t_of_phi(model_bits, l * cfg.subcarrier_bw, b_prime, phi)
        t_bar = float(np.max(t_cp + t_mu))
        b_gen = optimal_generation(min(t_bar, cfg.t_max), b_old, svc,
                                   cfg.gen_batch)
        history.append(t_bar)

        if (np.max(np.abs(l - l_old)) < eps
                and np.max(np.abs(phi - phi_old)) < eps
                and abs(b_gen - b_old) < 1):
            break

    t_mu = pw.t_of_phi(model_bits, l * cfg.subcarrier_bw, b_prime, phi)
    e_mu = phi * t_mu
    t_bar = float(np.max(t_cp + t_mu))
    t_rsu = inference_time(svc, b_gen) + rsu_train_time(
        max(b_gen // cfg.gen_batch, 1))
    return RoundPlan(alpha=alpha, selected=idx, l=l, phi=phi, b_gen=b_gen,
                     t_cp=t_cp, t_mu=t_mu, t_bar=t_bar,
                     e_total=e_cp + e_mu, t_rsu=t_rsu, bcd_iters=it,
                     history=history, selection=sel)

"""Theorem 1 convergence upper bound (paper Sec. III-C2).

L(w(T,Th)) - L(w*) <= chi^{hT} Theta + (1 - chi^{hT}) psi Lambda
  chi    = 1 - 2 mu eta + 2 mu rho eta^2          (rho = smoothness `varrho`)
  psi    = beta ((eta rho + 1)^h - 1) / (rho (1 + chi^h))
  Lambda = kappa1 sum_n rho_n (sigma_n + lambda_n) + kappa2 lambda_a

Requires eta < 1/rho for chi < 1 (contraction).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ConvergenceParams:
    beta: float = 1.0          # Lipschitz constant of L_n (Assumption 1)
    varrho: float = 10.0       # smoothness (Assumption 2)
    mu: float = 0.5            # strong convexity (Assumption 3)
    eta: float = 0.01          # learning rate (< 1/varrho)
    h: int = 4                 # local steps per round
    sigma: float = 0.1         # SGD variance bound (Assumption 5)
    lambda_a: float = 0.05     # AIGC model divergence bound (Assumption 4)
    theta: float = 1.0         # L(w0) - L(w*)


def chi(p: ConvergenceParams) -> float:
    return 1.0 - 2 * p.mu * p.eta + 2 * p.mu * p.varrho * p.eta ** 2


def psi(p: ConvergenceParams) -> float:
    c = chi(p)
    return p.beta * ((p.eta * p.varrho + 1) ** p.h - 1) / (p.varrho * (1 + c ** p.h))


def big_lambda(p: ConvergenceParams, rhos, lambdas, kappa1: float,
               kappa2: float) -> float:
    rhos = np.asarray(rhos, np.float64)
    lambdas = np.asarray(lambdas, np.float64)
    return float(kappa1 * np.sum(rhos * (p.sigma + lambdas)) + kappa2 * p.lambda_a)


def bound(p: ConvergenceParams, T: int, rhos, lambdas, kappa1: float,
          kappa2: float) -> float:
    """Theorem 1 RHS after T global rounds of h local steps."""
    assert p.eta < 1.0 / p.varrho, "Theorem 1 requires eta < 1/varrho"
    c = chi(p)
    lam = big_lambda(p, rhos, lambdas, kappa1, kappa2)
    decay = c ** (p.h * T)
    return decay * p.theta + (1.0 - decay) * psi(p) * lam


def bound_curve(p: ConvergenceParams, T_max: int, rhos, lambdas, kappa1,
                kappa2) -> np.ndarray:
    return np.array([bound(p, t, rhos, lambdas, kappa1, kappa2)
                     for t in range(T_max + 1)])

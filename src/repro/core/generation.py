"""SUBP4 — data-generation amount (paper Sec. V-B4, eq. 12-13, 47-48).

The RSU generates images while vehicles train; the optimal count fills the
straggler window:
    b* = floor( (max_n (T_cp + T_mu) - T_s^cp(b_prev)) / t0 )        (eq. 48)
with t0 = sum_t d_m,t / f_rsu the per-image diffusion inference latency
(eq. 12) and T_s^cp the augmented-model training time (eq. 13).

Generated labels are spread uniformly (IID target distribution, Sec. V-B4).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core.gpu_model import GpuModelConsts, CONSTS, rsu_train_time


@dataclass(frozen=True)
class DiffusionService:
    steps: int = 50                 # I inference steps per image
    d_cycles: float = 1.2e7         # cycles per step (d_m,t)
    f_rsu: float = 12.0e9           # RSU inference capacity (Hz)

    @property
    def t_per_image(self) -> float:
        """t0 in eq. (12)."""
        return self.steps * self.d_cycles / self.f_rsu


def inference_time(svc: DiffusionService, b: int) -> float:
    """Eq. (12): T_inf = b * t0."""
    return b * svc.t_per_image


def optimal_generation(t_bar: float, b_prev: int, svc: DiffusionService,
                       batch_size: int = 64,
                       gpu: GpuModelConsts = CONSTS) -> int:
    """Eq. (48). t_bar = max_n(T_cp + T_mu) of the selected vehicles."""
    t_train_prev = rsu_train_time(max(b_prev // batch_size, 1), gpu)
    budget = t_bar - t_train_prev
    if budget <= 0:
        return 0
    return int(np.floor(budget / svc.t_per_image))


def label_schedule(b: int, num_classes: int) -> np.ndarray:
    """Uniform per-label counts for b images (IID target, Sec. V-B4)."""
    base = b // num_classes
    extra = b % num_classes
    out = np.full(num_classes, base, np.int64)
    out[:extra] += 1
    return out

"""GPU execution-time and power models (paper Sec. IV-A3, eq. 6-8).

T_cp = t0 + c1 b theta_mem / f_mem + c2 b theta_core / f_core
p_cp = p_G0 + zeta_mem f_mem + zeta_core V_core^2 f_core
E_cp = p_cp * T_cp
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.mobility import Vehicle


@dataclass(frozen=True)
class GpuModelConsts:
    t0: float = 0.01            # task-independent launch overhead (s)
    c1: float = 1.0             # data-fetch cycle scale
    c2: float = 1.0             # compute cycle scale
    theta_mem: float = 2.0e7    # mem cycles per mini-batch
    theta_core: float = 8.0e7   # core cycles per mini-batch
    p_g0: float = 5.0           # static power (W)
    zeta_mem: float = 2.0e-9    # W per memory Hz
    zeta_core: float = 8.0e-9   # W per (V^2 * core Hz)


CONSTS = GpuModelConsts()


def train_time(v: Vehicle, batches: int, c: GpuModelConsts = CONSTS) -> float:
    """Eq. (6): one local-training pass of `batches` mini-batches."""
    return (c.t0 + c.c1 * batches * c.theta_mem / v.f_mem
            + c.c2 * batches * c.theta_core / v.f_core)


def runtime_power(v: Vehicle, c: GpuModelConsts = CONSTS) -> float:
    """Eq. (7)."""
    return c.p_g0 + c.zeta_mem * v.f_mem + c.zeta_core * v.v_core ** 2 * v.f_core


def train_energy(v: Vehicle, batches: int, c: GpuModelConsts = CONSTS) -> float:
    """Eq. (8): E = p * T."""
    return runtime_power(v, c) * train_time(v, batches, c)


# ---------------------------------------------------------------------------
# Vectorized variants (array-level SUBP1 selection / batched planner). Same
# float-op order as the scalar functions above, so results are bitwise equal
# elementwise.
# ---------------------------------------------------------------------------
def train_times(f_mem, f_core, batches: int,
                c: GpuModelConsts = CONSTS) -> "np.ndarray":
    """Eq. (6) over [N] frequency arrays."""
    return (c.t0 + c.c1 * batches * c.theta_mem / f_mem
            + c.c2 * batches * c.theta_core / f_core)


def runtime_powers(f_mem, f_core, v_core,
                   c: GpuModelConsts = CONSTS) -> "np.ndarray":
    """Eq. (7) over [N] capability arrays."""
    return c.p_g0 + c.zeta_mem * f_mem + c.zeta_core * v_core ** 2 * f_core


# RSU GPU: nominal vehicle-class core clock scaled by the Sec. IV-A5
# speedup. Named so the jitted planner (core/planner.py) derives the same
# eq. 13 constants as this reference instead of re-hard-coding them.
RSU_F_CORE = 1.5e9
RSU_SPEEDUP = 8.0


def rsu_train_time(batches: int, c: GpuModelConsts = CONSTS,
                   speedup: float = RSU_SPEEDUP) -> float:
    """Eq. (13): augmented-model training on the RSU GPU (faster than
    vehicle GPUs by `speedup`)."""
    return (c.t0 + (c.c1 * batches * c.theta_mem + c.c2 * batches * c.theta_core)
            / (RSU_F_CORE * speedup))

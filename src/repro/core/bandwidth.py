"""SUBP2 — bandwidth (subcarrier) allocation by Lagrange multipliers / KKT
(paper Sec. V-B2, eq. 33-38, Algorithm 1).

min_{l} T_bar  s.t.  A_n + B_n/l_n <= T_bar  (delay),
                     C_n + D_n/l_n <= E_bar  (energy),
                     sum l_n <= M,  l_n >= l_min.

KKT stationarity gives l_n* = sqrt((lambda1_n B_n + lambda2 D_n)/lambda3)
(eq. 38); the multipliers are driven by projected subgradient ascent
(Algorithm 1). The relaxed fractional l_n is the paper's expected number of
subcarriers (eq. 35).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class BandwidthResult:
    l: np.ndarray          # [N] fractional subcarriers
    t_bar: float           # resulting max delay
    iters: int
    converged: bool


def project_budget(l: np.ndarray, M: float, l_min: float) -> np.ndarray:
    """Project l onto {sum l <= M, l >= l_min} by iterated rescaling.

    Precondition: the input already satisfies l >= l_min (solve_bandwidth
    clips to [l_min, M] before projecting) — an already-under-budget input
    is returned untouched, so entries below the floor stay there.

    A single rescale `l * (M / sum(l))` followed by the l_min floor can
    leave sum(l) > M when the floor binds on some entries after rescaling.
    Water-fill instead: pin floored entries at l_min and rescale the rest
    into the remaining budget until no new entry falls below the floor.
    When no floor binds this is exactly the single rescale. If the budget
    is infeasible (n * l_min > M) every entry pins at l_min — the floor
    constraint wins, and sum(l) = n * l_min is the best achievable.
    """
    pinned = np.zeros(l.shape[0], bool)
    for _ in range(l.shape[0]):
        s_pin = l_min * float(np.count_nonzero(pinned))
        s_free = float(l[~pinned].sum())
        if s_pin + s_free <= M:
            break
        scale = max(M - s_pin, 0.0) / max(s_free, 1e-300)
        l = np.where(pinned, l_min, l * scale)
        newly = ~pinned & (l < l_min)
        if not newly.any():
            break
        pinned |= newly
        l = np.where(pinned, l_min, l)
    return l


def solve_bandwidth(A: np.ndarray, B: np.ndarray, C: np.ndarray,
                    D: np.ndarray, M: float, e_bar: float,
                    l_min: float = 0.05, step: float = 0.05,
                    max_iter: int = 500, tol: float = 1e-5) -> BandwidthResult:
    """A,B: delay terms; C,D: energy terms (per selected vehicle)."""
    n = A.shape[0]
    if n == 0:
        return BandwidthResult(np.zeros(0), 0.0, 0, True)
    lam1 = np.ones(n)
    lam2 = 1.0
    lam3 = 1.0
    l = np.full(n, M / n)
    prev = l.copy()
    it = 0
    for it in range(1, max_iter + 1):
        # eq. (38)
        l = np.sqrt((lam1 * B + lam2 * D) / max(lam3, 1e-9))
        l = np.clip(l, l_min, M)
        l = project_budget(l, M, l_min)
        t_bar = float(np.max(A + B / l))
        # subgradient ascent on the multipliers (Algorithm 1 lines 2-4)
        g1 = A + B / l - t_bar                  # <=0 slack per vehicle
        g2 = float(np.sum(C + D / l) - e_bar * n)
        g3 = float(l.sum() - M)
        lam1 = np.maximum(lam1 + step * g1, 0.0) + 1e-12
        lam2 = max(lam2 + step * g2, 0.0) + 1e-12
        lam3 = max(lam3 + step * g3, 1e-6)
        if np.max(np.abs(l - prev)) < tol:
            return BandwidthResult(l, t_bar, it, True)
        prev = l.copy()
    return BandwidthResult(l, float(np.max(A + B / l)), it, False)


def equal_share(n: int, M: float) -> np.ndarray:
    """Baseline: uniform split of the M subcarriers."""
    return np.full(n, M / max(n, 1))

"""EMD data-heterogeneity metric and the GenFV weighted policy
(paper Sec. III-C1, eq. 3-4).

EMD_n = sum_i | p_n(y=i) - p(y=i) |     (global reference p = uniform 1/Y)
kappa2 = (EMD_bar / 2)^2,  kappa1 = 1 - kappa2
omega^t = kappa1 * sum_n rho_n omega_n + kappa2 * omega_a
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def label_histogram(labels, num_classes: int) -> np.ndarray:
    """Normalized label distribution p_n(y=i) of one client's dataset."""
    labels = np.asarray(labels)
    h = np.bincount(labels, minlength=num_classes).astype(np.float64)
    return h / max(h.sum(), 1.0)


def emd(p_n: np.ndarray, p_global: np.ndarray | None = None) -> float:
    """EMD_n = sum_i |p_n(i) - p(i)|; p defaults to uniform (paper Sec. III-C1).

    Range [0, 2): 0 = IID, -> 2(Y-1)/Y for a single-class client.
    """
    p_n = np.asarray(p_n, np.float64)
    if p_global is None:
        p_global = np.full_like(p_n, 1.0 / p_n.shape[-1])
    return float(np.abs(p_n - p_global).sum(-1))


def emd_many(hists: np.ndarray, p_global: np.ndarray | None = None) -> np.ndarray:
    hists = np.asarray(hists, np.float64)
    if p_global is None:
        p_global = np.full(hists.shape[-1], 1.0 / hists.shape[-1])
    return np.abs(hists - p_global).sum(-1)


def mean_emd(emds: Sequence[float]) -> float:
    """EMD_bar over the participating set (paper: average data quality)."""
    emds = np.asarray(list(emds), np.float64)
    return float(emds.mean()) if emds.size else 0.0


def kappas(emd_bar: float) -> tuple[float, float]:
    """(kappa1, kappa2) from eq. (4): kappa2 = (EMD_bar/2)^2 clipped to [0,1]."""
    k2 = min(max((emd_bar / 2.0) ** 2, 0.0), 1.0)
    return 1.0 - k2, k2


def data_weights(sizes: Sequence[int]) -> np.ndarray:
    """rho_n = |D_n| / sum |D_n| over the selected set."""
    sizes = np.asarray(list(sizes), np.float64)
    return sizes / max(sizes.sum(), 1.0)


def aggregate(models: Sequence, rhos: Sequence[float], aug_model, emd_bar: float):
    """Eq. (4): omega = kappa1 * sum rho_n omega_n + kappa2 * omega_a.

    models: list of parameter pytrees; aug_model: pytree (same structure).
    """
    k1, k2 = kappas(emd_bar)
    rhos = np.asarray(list(rhos), np.float64)

    def combine(*leaves):
        fed = sum(float(r) * leaf.astype(jnp.float32)
                  for r, leaf in zip(rhos, leaves[:-1]))
        out = k1 * fed + k2 * leaves[-1].astype(jnp.float32)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(combine, *models, aug_model)


def aggregate_stacked(stacked, weights, aug_model, aug_weight):
    """On-device eq. (4) over a stacked pytree: each leaf of `stacked` has a
    leading client axis [K, ...] and is reduced with `weights` [K] (already
    kappa1 * rho_n, zero on padded slots), then kappa2 * omega_a is added.

    Device-side replacement for `aggregate`'s host loop; traced inside the
    fleet engine's fused dispatch (fl/fleet.py) so local SGD and aggregation
    ship as one XLA program.

    The weighted reduction is unrolled left-to-right rather than expressed as
    `einsum('k,k...->...')`: XLA may split an einsum/reduce differently per
    bucket size (1-ULP drift between K=4 and K=8 buckets), while explicit
    ordered adds are never reassociated, so zero-weight padded slots append
    exact `+ 0.0`s and the aggregate is bitwise identical across buckets.
    """
    def combine(s, a):
        s32 = s.astype(jnp.float32)
        fed = weights[0] * s32[0]
        for i in range(1, s.shape[0]):
            fed = fed + weights[i] * s32[i]
        out = fed + aug_weight * a.astype(jnp.float32)
        return out.astype(s.dtype)

    return jax.tree.map(combine, stacked, aug_model)


def aggregate_stacked_guarded(stacked, weights, aug_model, aug_weight,
                              fallback):
    """`aggregate_stacked` with a per-client finiteness guard: clients whose
    update contains any NaN/Inf leaf are excluded from the federated term and
    the surviving weights renormalized (fl/faults.py poison injection). If
    EVERY client is rejected, the federated mass is redirected to `fallback`
    (the round-start global), so a fully-poisoned round degrades to
    "no federated progress" instead of collapsing the model toward zero.

    Returns (aggregated, finite_mask [K] bool). Still a single traced
    reduction — the mask is an all-leaves `isfinite` all-reduce per client,
    fused into the same XLA program as the weighted sum.

    Numerically neutral when every client is finite: rows pass through
    `where(True, x, 0) = x`, the renormalization scale is `s/s = 1.0` and
    `x * 1.0 = x` under IEEE-754, and the reduction stays the same ordered
    unrolled chain as the unguarded kernel. NOTE this holds for the
    aggregation epilogue in exact IEEE terms, but the guarded fleet dispatch
    is still a *different fused XLA program* than the unguarded one, and the
    upstream vmapped SGD may fuse differently (ULP-level loss drift) — which
    is why fl/rounds.py dispatches this kernel only when a poisoned update
    is actually inside the batch, keeping clean rounds bitwise on the seed
    program (tests/test_faults.py pins that equivalence).
    """
    leaves = jax.tree_util.tree_leaves(stacked)
    finite = jnp.ones(leaves[0].shape[0], bool)
    for leaf in leaves:
        flat = leaf.reshape(leaf.shape[0], -1)
        finite = finite & jnp.all(jnp.isfinite(flat), axis=1)
    w = weights * finite
    s_all, s_fin = weights.sum(), w.sum()
    # keep the federated mass kappa1 (= weights.sum over real slots) constant:
    # surviving clients absorb the rejected clients' share.
    scale = jnp.where(s_fin > 0, s_all / s_fin, 0.0)

    def combine(s, a, fb):
        s32 = s.astype(jnp.float32)
        fed = w[0] * jnp.where(finite[0], s32[0], 0.0)
        for i in range(1, s.shape[0]):
            fed = fed + w[i] * jnp.where(finite[i], s32[i], 0.0)
        fed = jnp.where(s_fin > 0, fed * scale,
                        s_all * fb.astype(jnp.float32))
        out = fed + aug_weight * a.astype(jnp.float32)
        return out.astype(s.dtype)

    return jax.tree.map(combine, stacked, aug_model, fallback), finite


def tree_finite(tree) -> bool:
    """Host-side: every leaf of the pytree is finite (sequential-path poison
    filter)."""
    return all(bool(np.isfinite(np.asarray(l)).all())
               for l in jax.tree_util.tree_leaves(tree))


def add_weighted(params, models: Sequence, weights: Sequence[float]):
    """Host-side params + sum_i w_i * m_i with float32 accumulation —
    staleness-discounted merge of buffered late updates into an already
    aggregated global (fl/rounds.py)."""
    if not models:
        return params
    ws = [float(w) for w in weights]

    def combine(p, *ms):
        acc = p.astype(jnp.float32)
        for w, m in zip(ws, ms):
            acc = acc + w * m.astype(jnp.float32)
        return acc.astype(p.dtype)

    return jax.tree.map(combine, params, *models)


def lambda_bound(emd_n: float, g_n: float) -> float:
    """Eq. (3): gradient-divergence bound lambda_n <= EMD_n * g_n."""
    return emd_n * g_n

"""EMD data-heterogeneity metric and the GenFV weighted policy
(paper Sec. III-C1, eq. 3-4).

EMD_n = sum_i | p_n(y=i) - p(y=i) |     (global reference p = uniform 1/Y)
kappa2 = (EMD_bar / 2)^2,  kappa1 = 1 - kappa2
omega^t = kappa1 * sum_n rho_n omega_n + kappa2 * omega_a
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def label_histogram(labels, num_classes: int) -> np.ndarray:
    """Normalized label distribution p_n(y=i) of one client's dataset."""
    labels = np.asarray(labels)
    h = np.bincount(labels, minlength=num_classes).astype(np.float64)
    return h / max(h.sum(), 1.0)


def emd(p_n: np.ndarray, p_global: np.ndarray | None = None) -> float:
    """EMD_n = sum_i |p_n(i) - p(i)|; p defaults to uniform (paper Sec. III-C1).

    Range [0, 2): 0 = IID, -> 2(Y-1)/Y for a single-class client.
    """
    p_n = np.asarray(p_n, np.float64)
    if p_global is None:
        p_global = np.full_like(p_n, 1.0 / p_n.shape[-1])
    return float(np.abs(p_n - p_global).sum(-1))


def emd_many(hists: np.ndarray, p_global: np.ndarray | None = None) -> np.ndarray:
    hists = np.asarray(hists, np.float64)
    if p_global is None:
        p_global = np.full(hists.shape[-1], 1.0 / hists.shape[-1])
    return np.abs(hists - p_global).sum(-1)


def mean_emd(emds: Sequence[float]) -> float:
    """EMD_bar over the participating set (paper: average data quality)."""
    emds = np.asarray(list(emds), np.float64)
    return float(emds.mean()) if emds.size else 0.0


def kappas(emd_bar: float) -> tuple[float, float]:
    """(kappa1, kappa2) from eq. (4): kappa2 = (EMD_bar/2)^2 clipped to [0,1]."""
    k2 = min(max((emd_bar / 2.0) ** 2, 0.0), 1.0)
    return 1.0 - k2, k2


def data_weights(sizes: Sequence[int]) -> np.ndarray:
    """rho_n = |D_n| / sum |D_n| over the selected set."""
    sizes = np.asarray(list(sizes), np.float64)
    return sizes / max(sizes.sum(), 1.0)


def aggregate(models: Sequence, rhos: Sequence[float], aug_model, emd_bar: float):
    """Eq. (4): omega = kappa1 * sum rho_n omega_n + kappa2 * omega_a.

    models: list of parameter pytrees; aug_model: pytree (same structure).
    """
    k1, k2 = kappas(emd_bar)
    rhos = np.asarray(list(rhos), np.float64)

    def combine(*leaves):
        fed = sum(float(r) * leaf.astype(jnp.float32)
                  for r, leaf in zip(rhos, leaves[:-1]))
        out = k1 * fed + k2 * leaves[-1].astype(jnp.float32)
        return out.astype(leaves[0].dtype)

    return jax.tree.map(combine, *models, aug_model)


def aggregate_stacked(stacked, weights, aug_model, aug_weight):
    """On-device eq. (4) over a stacked pytree: each leaf of `stacked` has a
    leading client axis [K, ...] and is reduced with `weights` [K] (already
    kappa1 * rho_n, zero on padded slots), then kappa2 * omega_a is added.

    Device-side replacement for `aggregate`'s host loop; traced inside the
    fleet engine's fused dispatch (fl/fleet.py) so local SGD and aggregation
    ship as one XLA program.

    The weighted reduction is unrolled left-to-right rather than expressed as
    `einsum('k,k...->...')`: XLA may split an einsum/reduce differently per
    bucket size (1-ULP drift between K=4 and K=8 buckets), while explicit
    ordered adds are never reassociated, so zero-weight padded slots append
    exact `+ 0.0`s and the aggregate is bitwise identical across buckets.
    """
    def combine(s, a):
        s32 = s.astype(jnp.float32)
        fed = weights[0] * s32[0]
        for i in range(1, s.shape[0]):
            fed = fed + weights[i] * s32[i]
        out = fed + aug_weight * a.astype(jnp.float32)
        return out.astype(s.dtype)

    return jax.tree.map(combine, stacked, aug_model)


def lambda_bound(emd_n: float, g_n: float) -> float:
    """Eq. (3): gradient-divergence bound lambda_n <= EMD_n * g_n."""
    return emd_n * g_n

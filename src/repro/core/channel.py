"""OFDMA uplink model (paper Sec. IV-A4, eq. 9-11).

r_n = l_n W log2(1 + phi_n h0 d_n^-gamma / N0)
T_mu = s(omega) / r_n ;  E_mu = phi_n T_mu
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GenFVConfig


def noise_watts(cfg: GenFVConfig) -> float:
    """Noise power over one subchannel: N0[dBm/Hz] integrated over W."""
    psd = 10 ** ((cfg.noise_power_dbm - 30.0) / 10.0)   # W/Hz
    return psd * cfg.subcarrier_bw


def snr(cfg: GenFVConfig, phi: float, dist: float) -> float:
    """phi h0 d^-gamma / N0 (eq. 9 inner term)."""
    return phi * cfg.unit_channel_gain * dist ** (-cfg.path_loss_exp) / noise_watts(cfg)


def uplink_rate(cfg: GenFVConfig, l_n: float, phi: float, dist: float) -> float:
    """Eq. (9): bits/s given l_n subcarriers (fractional l_n allowed by the
    SUBP2 relaxation), power phi (W) and distance dist (m)."""
    return l_n * cfg.subcarrier_bw * np.log2(1.0 + snr(cfg, phi, dist))


def upload_time(cfg: GenFVConfig, model_bits: float, l_n: float, phi: float,
                dist: float) -> float:
    """Eq. (10)."""
    r = uplink_rate(cfg, l_n, phi, dist)
    return float(model_bits / max(r, 1e-9))


def upload_energy(cfg: GenFVConfig, model_bits: float, l_n: float, phi: float,
                  dist: float) -> float:
    """Eq. (11)."""
    return float(phi * upload_time(cfg, model_bits, l_n, phi, dist))

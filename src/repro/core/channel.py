"""OFDMA uplink model (paper Sec. IV-A4, eq. 9-11).

r_n = l_n W log2(1 + phi_n h0 d_n^-gamma / N0)
T_mu = s(omega) / r_n ;  E_mu = phi_n T_mu

`gain_db` is a slow-fading shadowing offset on the unit channel gain h0
(0 dB = the paper's memoryless nominal channel). The repro.sim world evolves
it per vehicle as an AR(1) log-normal process so SNR is time-correlated
between rounds; the legacy path always passes 0, where 10^(0/10) = 1.0
multiplies exactly and reproduces the seed numbers bitwise.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GenFVConfig


def noise_watts(cfg: GenFVConfig) -> float:
    """Noise power over one subchannel: N0[dBm/Hz] integrated over W."""
    psd = 10 ** ((cfg.noise_power_dbm - 30.0) / 10.0)   # W/Hz
    return psd * cfg.subcarrier_bw


def shadow_linear(gain_db) -> float | np.ndarray:
    """dB shadowing offset -> linear multiplier on h0."""
    return 10.0 ** (np.asarray(gain_db, np.float64) / 10.0)


def snr(cfg: GenFVConfig, phi: float, dist: float,
        gain_db: float = 0.0) -> float:
    """phi h0 d^-gamma / N0 (eq. 9 inner term), h0 shadowed by gain_db."""
    h0 = cfg.unit_channel_gain * shadow_linear(gain_db)
    return phi * h0 * dist ** (-cfg.path_loss_exp) / noise_watts(cfg)


def uplink_rate(cfg: GenFVConfig, l_n: float, phi: float, dist: float,
                gain_db: float = 0.0) -> float:
    """Eq. (9): bits/s given l_n subcarriers (fractional l_n allowed by the
    SUBP2 relaxation), power phi (W) and distance dist (m)."""
    return l_n * cfg.subcarrier_bw * np.log2(1.0 + snr(cfg, phi, dist, gain_db))


def upload_time(cfg: GenFVConfig, model_bits: float, l_n: float, phi: float,
                dist: float, gain_db: float = 0.0) -> float:
    """Eq. (10)."""
    r = uplink_rate(cfg, l_n, phi, dist, gain_db)
    return float(model_bits / max(r, 1e-9))


def upload_energy(cfg: GenFVConfig, model_bits: float, l_n: float, phi: float,
                  dist: float, gain_db: float = 0.0) -> float:
    """Eq. (11)."""
    return float(phi * upload_time(cfg, model_bits, l_n, phi, dist, gain_db))


# ---------------------------------------------------------------------------
# Vectorized variants (array-level SUBP1 selection / batched planner). Same
# float-op order as the scalar chain above, so results are bitwise equal
# elementwise.
# ---------------------------------------------------------------------------
def snrs(cfg: GenFVConfig, phi, dist, gain_db=0.0) -> np.ndarray:
    """Eq. (9) inner term over [N] arrays."""
    h0 = cfg.unit_channel_gain * shadow_linear(gain_db)
    return phi * h0 * np.asarray(dist, np.float64) ** (-cfg.path_loss_exp) \
        / noise_watts(cfg)


def upload_times(cfg: GenFVConfig, model_bits: float, l_n, phi, dist,
                 gain_db=0.0) -> np.ndarray:
    """Eq. (10) over [N] arrays."""
    r = l_n * cfg.subcarrier_bw * np.log2(1.0 + snrs(cfg, phi, dist, gain_db))
    return model_bits / np.maximum(r, 1e-9)

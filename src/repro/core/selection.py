"""SUBP1 — large-communication-scale vehicle selection (paper Sec. V-A).

alpha_n = 1  iff  (T_n^cp + T_n^mu <= T_bar_n) AND (EMD_n <= EMD_hat)
with T_bar_n = min(t_hold_n, t_max)  (eq. 27-30).

Feasibility is checked with nominal resources (one subcarrier, max power),
since bandwidth/power are only optimized for the *selected* set afterwards.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import channel, gpu_model, mobility
from repro.core.mobility import Vehicle


@dataclass
class SelectionResult:
    alpha: np.ndarray                # [N] {0,1}
    t_bar: np.ndarray                # [N] per-vehicle deadline (eq. 27)
    t_cp: np.ndarray                 # nominal train time
    t_mu: np.ndarray                 # nominal upload time
    t_hold: np.ndarray | None = None  # [N] raw eq.-26 holding time (dropout
                                      # accounting: t_bar caps it at t_max)
    # lazy reason strings: (vids, emds, emd_hat) kept so the per-vehicle
    # explanation is only formatted when someone actually reads it (the hot
    # planner path never does)
    _reason_ctx: tuple | None = field(default=None, repr=False)
    _reasons: List[str] | None = field(default=None, repr=False)

    @property
    def reasons(self) -> List[str]:
        """Why each vehicle was kept/dropped (formatted on first access)."""
        if self._reasons is None:
            vids, emds, emd_hat = self._reason_ctx or ([], [], 0.0)
            total = self.t_cp + self.t_mu
            out = []
            for i, vid in enumerate(vids):
                if emds[i] > emd_hat:
                    out.append(
                        f"v{vid}: dropped (EMD {emds[i]:.2f} > {emd_hat})")
                elif total[i] > self.t_bar[i]:
                    out.append(f"v{vid}: dropped (T {total[i]:.2f}s > "
                               f"Tbar {self.t_bar[i]:.2f}s)")
                else:
                    out.append(f"v{vid}: selected")
            self._reasons = out
        return self._reasons


def select(cfg: GenFVConfig, fleet: List[Vehicle], model_bits: float,
           batches: int, emd_hat: float | None = None) -> SelectionResult:
    emd_hat = cfg.emd_threshold if emd_hat is None else emd_hat
    xs = np.array([v.x for v in fleet], np.float64)
    vs = np.array([v.v for v in fleet], np.float64)
    phi_max = np.array([v.phi_max for v in fleet], np.float64)
    f_mem = np.array([v.f_mem for v in fleet], np.float64)
    f_core = np.array([v.f_core for v in fleet], np.float64)
    gain_db = np.array([v.gain_db for v in fleet], np.float64)
    emds = np.array([v.emd for v in fleet], np.float64)
    vids = [v.vid for v in fleet]

    # eq. 26-27 deadline + nominal single-subcarrier/max-power budget, all
    # array-level (the vectorized helpers mirror the scalar float-op order,
    # so alpha is bitwise-identical to the per-vehicle reference loop)
    t_hold = mobility.holding_times(cfg, xs, vs)
    t_bar = np.minimum(t_hold, cfg.t_max)
    t_cp = gpu_model.train_times(f_mem, f_core, batches)
    dists = mobility.rsu_distances(cfg, xs)
    t_mu = channel.upload_times(cfg, model_bits, 1.0, phi_max, dists,
                                gain_db=gain_db)
    alpha = (~(emds > emd_hat) & ~(t_cp + t_mu > t_bar)).astype(np.int32)
    return SelectionResult(alpha, t_bar, t_cp, t_mu, t_hold,
                           _reason_ctx=(vids, emds, emd_hat))


def dropout_mask(cfg: GenFVConfig, fleet: List[Vehicle],
                 selected: List[int], t_round: float) -> np.ndarray:
    """Survival mask over `selected`: True where the vehicle's eq.-26 holding
    time covers the realized round duration `t_round`.

    SUBP1 admits vehicles whose *nominal* budget fits inside min(t_hold,
    t_max), but the realized straggler window t_bar is only known after
    SUBP2-4 run for the selected set — a vehicle can leave coverage before
    the synchronous round closes anyway, and its update is discarded
    (commit-at-window-end semantics; rationale in DESIGN.md §repro.sim).
    repro.sim threads the dropout count into RoundLog.
    """
    if not selected:
        return np.zeros(0, bool)
    xs = np.array([fleet[i].x for i in selected])
    vs = np.array([fleet[i].v for i in selected])
    return mobility.holding_times(cfg, xs, vs) >= t_round


def select_random(rng: np.random.Generator, fleet, k: int) -> np.ndarray:
    """FedAvg baseline: uniform random selection of k vehicles."""
    alpha = np.zeros(len(fleet), np.int32)
    idx = rng.choice(len(fleet), size=min(k, len(fleet)), replace=False)
    alpha[idx] = 1
    return alpha


def select_no_emd(cfg: GenFVConfig, fleet, model_bits: float,
                  batches: int) -> np.ndarray:
    """'No EMD' baseline: keep only the deadline constraint (eq. 28)."""
    res = select(cfg, fleet, model_bits, batches, emd_hat=np.inf)
    return res.alpha


def select_madca(cfg: GenFVConfig, fleet, model_bits: float, batches: int,
                 success_prob: float = 0.8) -> np.ndarray:
    """MADCA-FL-style baseline [5]: select vehicles whose probability of
    finishing within their holding time exceeds `success_prob`, ignoring
    data heterogeneity. Completion probability is estimated from the
    speed-noise model (sigma = k*v)."""
    alpha = np.zeros(len(fleet), np.int32)
    for i, v in enumerate(fleet):
        t_need = (gpu_model.train_time(v, batches)
                  + channel.upload_time(cfg, model_bits, 1.0, v.phi_max,
                                        mobility.rsu_distance(cfg, v.x),
                                        gain_db=v.gain_db))
        # holding time at +/- 1.28 sigma speed (10%/90% quantiles)
        s = mobility.remaining_distance(cfg, v.x, v.v)
        v_hi = abs(v.v) * (1 + 1.28 * cfg.sigma_k) / 3.6
        t_hold_lo = max(s, 0.0) / max(v_hi, 1e-9)
        p_ok = 1.0 if t_need <= t_hold_lo else (
            0.0 if t_need > mobility.holding_time(cfg, v.x, v.v) else 0.5)
        if p_ok >= success_prob and t_need <= cfg.t_max:
            alpha[i] = 1
    return alpha


def select_ocean(cfg: GenFVConfig, fleet, model_bits: float, batches: int,
                 round_idx: int, total_rounds: int) -> np.ndarray:
    """OCEAN-a-style baseline [30]: long-term energy-aware selection with a
    'later-is-better' participation ramp — the admitted fraction grows with
    the round index."""
    frac = 0.3 + 0.7 * min(round_idx / max(total_rounds - 1, 1), 1.0)
    scores = []
    for v in fleet:
        e = (gpu_model.train_energy(v, batches)
             + channel.upload_energy(cfg, model_bits, 1.0, v.phi_max,
                                     mobility.rsu_distance(cfg, v.x),
                                     gain_db=v.gain_db))
        scores.append(e)
    order = np.argsort(scores)                      # cheapest energy first
    k = max(1, int(round(frac * len(fleet))))
    alpha = np.zeros(len(fleet), np.int32)
    alpha[order[:k]] = 1
    return alpha

"""Class-conditional UNet noise predictor for 32x32 images (DDPM backbone,
paper Sec. III-B / Sec. VI-A2). Pure functional JAX.

Topology: 32 -> 16 -> 8 resolution, [c, 2c, 4c] channels, residual blocks
with GroupNorm+SiLU, a self-attention block at 8x8, sinusoidal time
embedding + learned class embedding injected per block (FiLM-style shift).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def _conv_init(key, k, c_in, c_out, scale=None):
    fan_in = k * k * c_in
    scale = (2.0 / fan_in) ** 0.5 if scale is None else scale
    return jax.random.normal(key, (k, k, c_in, c_out)) * scale


def conv(w, x, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def groupnorm(p, x, groups=8, eps=1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def time_embedding(t, dim):
    """Sinusoidal embedding of integer timestep t: [B] -> [B, dim]."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _res_init(key, c_in, c_out, emb):
    ks = jax.random.split(key, 4)
    p = {"gn1": _gn_init(c_in), "conv1": _conv_init(ks[0], 3, c_in, c_out),
         "emb": jax.random.normal(ks[1], (emb, c_out)) * (1.0 / emb) ** 0.5,
         "gn2": _gn_init(c_out),
         "conv2": _conv_init(ks[2], 3, c_out, c_out, scale=1e-3)}
    if c_in != c_out:
        p["proj"] = _conv_init(ks[3], 1, c_in, c_out)
    return p


def _res_apply(p, x, emb):
    h = conv(p["conv1"], jax.nn.silu(groupnorm(p["gn1"], x)))
    h = h + (emb @ p["emb"])[:, None, None, :]
    h = conv(p["conv2"], jax.nn.silu(groupnorm(p["gn2"], h)))
    if "proj" in p:
        x = conv(p["proj"], x)
    return x + h


def _attn_init(key, c):
    ks = jax.random.split(key, 4)
    s = (1.0 / c) ** 0.5
    return {"gn": _gn_init(c),
            "wq": jax.random.normal(ks[0], (c, c)) * s,
            "wk": jax.random.normal(ks[1], (c, c)) * s,
            "wv": jax.random.normal(ks[2], (c, c)) * s,
            "wo": jax.random.normal(ks[3], (c, c)) * 1e-3}


def _attn_apply(p, x):
    B, H, W, C = x.shape
    h = groupnorm(p["gn"], x).reshape(B, H * W, C)
    q, k, v = h @ p["wq"], h @ p["wk"], h @ p["wv"]
    a = jax.nn.softmax(q @ k.transpose(0, 2, 1) * (C ** -0.5), axis=-1)
    out = (a @ v) @ p["wo"]
    return x + out.reshape(B, H, W, C)


def init_unet(key, num_classes: int, base: int = 64, emb: int = 256
              ) -> Dict[str, Any]:
    c1, c2, c3 = base, base * 2, base * 4
    ks = jax.random.split(key, 20)
    return {
        "cls_emb": jax.random.normal(ks[0], (num_classes, emb)) * 0.02,
        "t_w1": jax.random.normal(ks[1], (emb, emb)) * (1.0 / emb) ** 0.5,
        "t_w2": jax.random.normal(ks[2], (emb, emb)) * (1.0 / emb) ** 0.5,
        "in": _conv_init(ks[3], 3, 3, c1),
        "d1a": _res_init(ks[4], c1, c1, emb),
        "down1": _conv_init(ks[5], 3, c1, c2),      # stride 2: 32->16
        "d2a": _res_init(ks[6], c2, c2, emb),
        "down2": _conv_init(ks[7], 3, c2, c3),      # stride 2: 16->8
        "mid1": _res_init(ks[8], c3, c3, emb),
        "mid_attn": _attn_init(ks[9], c3),
        "mid2": _res_init(ks[10], c3, c3, emb),
        "u2": _res_init(ks[11], c3 + c2, c2, emb),  # 16
        "u1": _res_init(ks[12], c2 + c1, c1, emb),  # 32
        "out_gn": _gn_init(c1),
        "out": _conv_init(ks[13], 3, c1, 3, scale=1e-3),
    }


def unet_apply(p, x, t, y):
    """x: [B,32,32,3]; t: [B] int; y: [B] int class. Returns eps_hat."""
    emb = time_embedding(t, p["t_w1"].shape[0]) + p["cls_emb"][y]
    emb = jax.nn.silu(emb @ p["t_w1"]) @ p["t_w2"]

    h0 = conv(p["in"], x)                       # 32, c1
    h1 = _res_apply(p["d1a"], h0, emb)          # 32, c1
    h2 = conv(p["down1"], h1, stride=2)         # 16, c2
    h2 = _res_apply(p["d2a"], h2, emb)          # 16, c2
    h3 = conv(p["down2"], h2, stride=2)         # 8,  c3
    h3 = _res_apply(p["mid1"], h3, emb)
    h3 = _attn_apply(p["mid_attn"], h3)
    h3 = _res_apply(p["mid2"], h3, emb)

    u = jax.image.resize(h3, (h3.shape[0], 16, 16, h3.shape[-1]), "nearest")
    u = _res_apply(p["u2"], jnp.concatenate([u, h2], -1), emb)   # 16, c2
    u = jax.image.resize(u, (u.shape[0], 32, 32, u.shape[-1]), "nearest")
    u = _res_apply(p["u1"], jnp.concatenate([u, h1], -1), emb)   # 32, c1
    return conv(p["out"], jax.nn.silu(groupnorm(p["out_gn"], u)))

from repro.diffusion.ddpm import (DDPM, ddpm_loss, ddpm_sample, make_ddpm,
                                  q_sample)

"""DDPM (paper Sec. III-B, eq. 1-2): forward noising, noise-prediction loss,
and ancestral sampling, class-conditional.

q(x_t | x_{t-1}) = N(sqrt(1-lambda_t) x_{t-1}, lambda_t I)          (eq. 1)
L = E || eps - eps_theta(x_t, t) ||^2                               (eq. 2)
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.diffusion.unet import init_unet, unet_apply


@dataclass(frozen=True)
class DDPM:
    timesteps: int = 200
    beta_min: float = 1e-4
    beta_max: float = 0.02
    num_classes: int = 10
    base_width: int = 32

    def betas(self):
        return jnp.linspace(self.beta_min, self.beta_max, self.timesteps)

    def alpha_bars(self):
        return jnp.cumprod(1.0 - self.betas())


def make_ddpm(key, ddpm: DDPM):
    return init_unet(key, ddpm.num_classes, base=ddpm.base_width)


def q_sample(ddpm: DDPM, x0, t, eps):
    """Eq. (1) composed over t steps: x_t = sqrt(abar_t) x0 + sqrt(1-abar_t) eps."""
    abar = ddpm.alpha_bars()[t][:, None, None, None]
    return jnp.sqrt(abar) * x0 + jnp.sqrt(1.0 - abar) * eps


def ddpm_loss(params, ddpm: DDPM, key, x0, y):
    """Eq. (2)."""
    kt, ke = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.randint(kt, (B,), 0, ddpm.timesteps)
    eps = jax.random.normal(ke, x0.shape)
    x_t = q_sample(ddpm, x0, t, eps)
    eps_hat = unet_apply(params, x_t, t, y)
    return jnp.mean(jnp.square(eps - eps_hat))


@partial(jax.jit, static_argnums=(1,))
def _sample_loop(params, ddpm: DDPM, key, y):
    betas = ddpm.betas()
    alphas = 1.0 - betas
    abars = ddpm.alpha_bars()
    B = y.shape[0]

    def body(i, carry):
        x, k = carry
        t = ddpm.timesteps - 1 - i
        tb = jnp.full((B,), t, jnp.int32)
        eps_hat = unet_apply(params, x, tb, y)
        coef = betas[t] / jnp.sqrt(1.0 - abars[t])
        mean = (x - coef * eps_hat) / jnp.sqrt(alphas[t])
        k, kn = jax.random.split(k)
        noise = jax.random.normal(kn, x.shape)
        x = mean + jnp.where(t > 0, jnp.sqrt(betas[t]), 0.0) * noise
        return (x, k)

    k0, kx = jax.random.split(key)
    x = jax.random.normal(kx, (B, 32, 32, 3))
    x, _ = jax.lax.fori_loop(0, ddpm.timesteps, body, (x, k0))
    return jnp.clip(x, -1.0, 1.0)


def ddpm_sample(params, ddpm: DDPM, key, labels):
    """Ancestral sampling: labels [B] int -> images [B,32,32,3] in [-1,1]."""
    return _sample_loop(params, ddpm, key, jnp.asarray(labels, jnp.int32))

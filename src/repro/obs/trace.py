"""Span/event tracer with explicit-clock, JIT-aware timing.

`Obs` is the enabled tracer; `NULL_OBS` is the shared zero-overhead null
object every pipeline component holds by default. The two expose the same
surface, so call sites are unconditional — no ``if obs:`` branching in the
round loop — and the disabled path allocates nothing beyond the calls
themselves.

JIT-awareness is two policies, both opt-in per span:

* **Boundary fencing** — async dispatches make naive wall-clock timing lie
  (the host returns before the device finishes). A span whose `sync`
  attribute is set calls ``jax.block_until_ready`` on it at span EXIT only,
  so the fence lands on a span boundary and never inside a fused region.
  Fencing already-launched work is numerically inert: enabled and disabled
  runs stay bitwise-identical (tests/test_obs.py).
* **Compile tagging** — the first time a (name, key) pair is seen by this
  tracer the span is tagged ``stage="compile"`` (trace-and-compile cost
  lands there), later calls ``stage="execute"``. `key` should be whatever
  keys the jit cache — the fleet bucket size, the planner bucket, the guard
  flag. The tag is per-tracer: a second runner sharing jax's global jit
  cache will tag its own first call "compile" even though it hits the
  cache; DESIGN.md §Observability spells out this caveat.
"""
from __future__ import annotations

import sys
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["NULL_OBS", "NullObs", "Obs", "ProgressLogger", "Span",
           "Stopwatch", "VirtualClock", "log_line", "stopwatch"]


# ---------------------------------------------------------------------------
# Deterministic virtual wall-clock.
# ---------------------------------------------------------------------------
class VirtualClock:
    """An explicitly-advanced time source: calling it reads the current
    virtual time, `advance(dt)` moves it forward. Drop-in for the `clock`
    parameter of `Obs`/`Stopwatch`, and the simulation clock of
    `repro.fl.stream.StreamEngine` — the streaming round loop never reads
    `time.time()`/`time.monotonic()` (tests/test_obs.py lints for it), so
    same (seed, schedule) replays the identical event order anywhere."""
    __slots__ = ("t",)

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0.0:
            raise ValueError(f"virtual clock cannot run backwards (dt={dt})")
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# Clock helper (replaces benchmarks.common.timer, which returned a bare
# perf_counter float despite the name suggesting a context/callable).
# ---------------------------------------------------------------------------
class Stopwatch:
    """``with stopwatch() as sw: ...; sw.elapsed_s`` — explicit-clock
    wall timer. `elapsed_s` is live while the block runs and frozen at
    exit."""
    __slots__ = ("_clock", "t0", "_final")

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self.t0 = clock()
        self._final: Optional[float] = None

    @property
    def elapsed_s(self) -> float:
        return self._final if self._final is not None \
            else self._clock() - self.t0

    def __enter__(self) -> "Stopwatch":
        self.t0 = self._clock()
        self._final = None
        return self

    def __exit__(self, *exc) -> bool:
        self._final = self._clock() - self.t0
        return False


def stopwatch(clock: Callable[[], float] = time.perf_counter) -> Stopwatch:
    return Stopwatch(clock)


# ---------------------------------------------------------------------------
# Spans.
# ---------------------------------------------------------------------------
class Span:
    """One timed region. Produced by `Obs.span`; set `sync` inside the
    block to fence an async jax value at the span boundary."""
    __slots__ = ("_obs", "name", "key", "tags", "t0", "sync")

    def __init__(self, obs: "Obs", name: str, key, tags: Dict[str, Any]):
        self._obs = obs
        self.name = name
        self.key = key
        self.tags = tags
        self.sync = None
        self.t0 = 0.0

    def __enter__(self) -> "Span":
        self._obs._open.append(self.name)
        self.t0 = self._obs._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self.sync is not None:
            import jax
            jax.block_until_ready(self.sync)
        self._obs._close_span(self)
        return False


class _NullSpan:
    """Shared no-op span: `__enter__` returns the singleton, nothing is
    recorded. `sync` writes are swallowed (one slot, never read)."""
    __slots__ = ("sync",)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL_SPAN = _NullSpan()


# ---------------------------------------------------------------------------
# The tracer.
# ---------------------------------------------------------------------------
class Obs:
    """Enabled tracer + metrics registry.

    Parameters
    ----------
    clock: explicit time source (seconds, monotonic); injectable so tests
        can drive deterministic timestamps.
    meta: free-form run identification folded into every sink payload.
    """
    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 meta: Dict[str, Any] | None = None):
        from repro.obs.metrics import MetricsRegistry
        self._clock = clock
        self._t0 = clock()
        self.meta = dict(meta or {})
        self.events: List[Dict[str, Any]] = []
        self.metrics = MetricsRegistry()
        self._open: List[str] = []
        self._seen: set = set()

    # -- spans / events ----------------------------------------------------
    def span(self, name: str, key=None, **tags) -> Span:
        return Span(self, name, key, tags)

    def _close_span(self, sp: Span) -> None:
        end = self._clock()
        self._open.pop()
        seen_key = (sp.name, sp.key)
        if seen_key in self._seen:
            stage = "execute"
        else:
            self._seen.add(seen_key)
            stage = "compile" if sp.key is not None else "execute"
        dur = end - sp.t0
        self.events.append({"ph": "X", "name": sp.name,
                            "ts": sp.t0 - self._t0, "dur": dur,
                            "stage": stage, "tags": sp.tags})
        self.metrics.observe(f"span/{sp.name}", dur, stage=stage)

    def event(self, name: str, **tags) -> None:
        self.events.append({"ph": "i", "name": name,
                            "ts": self._clock() - self._t0, "tags": tags})

    @property
    def open_spans(self) -> int:
        return len(self._open)

    # -- metrics (delegation) ----------------------------------------------
    def count(self, name: str, value: float = 1, **tags) -> None:
        self.metrics.count(name, value, **tags)

    def gauge(self, name: str, value: float, **tags) -> None:
        self.metrics.gauge(name, value, **tags)

    def observe(self, name: str, value: float, **tags) -> None:
        self.metrics.observe(name, value, **tags)

    # -- scoping -----------------------------------------------------------
    def tagged(self, **tags) -> "_Tagged":
        """A view of this tracer that adds `tags` to every span/event/metric
        (e.g. ``obs.tagged(cell=3)`` for one sweep cell's runner)."""
        return _Tagged(self, tags)

    # -- sinks (implemented in obs/sinks.py) -------------------------------
    def metrics_payload(self, name: str = "run") -> Dict[str, Any]:
        from repro.obs.sinks import metrics_payload
        return metrics_payload(self, name)

    def save_metrics(self, name: str, directory: str | None = None) -> str:
        from repro.obs.sinks import save_metrics_artifact
        return save_metrics_artifact(self.metrics_payload(name), name,
                                     directory=directory)

    def write_trace(self, path: str) -> str:
        from repro.obs.sinks import write_trace
        return write_trace(self, path)

    def write_jsonl(self, path: str) -> str:
        from repro.obs.sinks import write_jsonl
        return write_jsonl(self, path)


class _Tagged:
    """Tag-scoped view of an `Obs` (same surface, extra tags merged in)."""
    __slots__ = ("_obs", "_tags")
    enabled = True

    def __init__(self, obs: Obs, tags: Dict[str, Any]):
        self._obs = obs
        self._tags = tags

    def span(self, name: str, key=None, **tags) -> Span:
        return self._obs.span(name, key=key, **{**self._tags, **tags})

    def event(self, name: str, **tags) -> None:
        self._obs.event(name, **{**self._tags, **tags})

    def count(self, name: str, value: float = 1, **tags) -> None:
        self._obs.count(name, value, **{**self._tags, **tags})

    def gauge(self, name: str, value: float, **tags) -> None:
        self._obs.gauge(name, value, **{**self._tags, **tags})

    def observe(self, name: str, value: float, **tags) -> None:
        self._obs.observe(name, value, **{**self._tags, **tags})

    def tagged(self, **tags) -> "_Tagged":
        return _Tagged(self._obs, {**self._tags, **tags})


class NullObs:
    """The disabled path: every method is a no-op, `span` hands back one
    shared context manager. No state, no allocation, no RNG, no device
    work — holding NULL_OBS is indistinguishable from having no obs code
    at all (the per-round overhead smoke in tests/test_obs.py bounds it)."""
    enabled = False
    __slots__ = ()

    def span(self, name, key=None, **tags):
        return _NULL_SPAN

    def event(self, name, **tags):
        pass

    def count(self, name, value=1, **tags):
        pass

    def gauge(self, name, value, **tags):
        pass

    def observe(self, name, value, **tags):
        pass

    def tagged(self, **tags):
        return self


NULL_OBS = NullObs()


# ---------------------------------------------------------------------------
# Rate-limited human-readable progress (replaces the bare print lines in
# fl/rounds.py::train and exp/sweep.py).
# ---------------------------------------------------------------------------
class ProgressLogger:
    """Per-key rate limiter over a render stream. A key's line is written
    at most once per `min_interval_s` (wall clock), except `force=True`
    (final-round summaries always land)."""

    def __init__(self, min_interval_s: float = 0.1,
                 clock: Callable[[], float] = time.monotonic, out=None):
        self.min_interval_s = min_interval_s
        self._clock = clock
        self._out = out
        self._last: Dict[str, float] = {}

    def emit(self, key: str, text: str, force: bool = False) -> bool:
        now = self._clock()
        last = self._last.get(key)
        if not force and last is not None \
                and now - last < self.min_interval_s:
            return False
        self._last[key] = now
        out = self._out if self._out is not None else sys.stdout
        out.write(text + "\n")
        return True


_PROGRESS = ProgressLogger()


def log_line(obs, key: str, text: str, force: bool = False,
             **fields) -> None:
    """Structured progress logging: record a `log` event on `obs` (when
    enabled) and render the human-readable line through the shared
    rate-limited ProgressLogger. The rendering side exists even when obs
    is disabled — `verbose=True` callers still see their lines."""
    if obs is not None and obs.enabled:
        obs.event("log", key=key, text=text, **fields)
    _PROGRESS.emit(key, text, force=force)

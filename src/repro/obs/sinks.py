"""Obs sinks: JSONL event log, Chrome/Perfetto trace export, and the
versioned ``repro.obs/metrics/v1`` artifact.

The metrics artifact lives alongside the `repro.exp` outputs (default
``artifacts/``, override via ``REPRO_ARTIFACTS``) as
``<name>.metrics.json``; `benchmarks/make_experiments_md.py` renders its
span distributions into the EXPERIMENTS.md per-phase timing table. The
``repro.obs/bench/v1`` tag is the shared BENCH_*.json envelope schema
(assembled by `benchmarks/common.py::record`).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List

SCHEMA_PREFIX = "repro.obs"
METRICS_SCHEMA = f"{SCHEMA_PREFIX}/metrics/v1"
BENCH_SCHEMA = f"{SCHEMA_PREFIX}/bench/v1"


def host_meta() -> Dict[str, Any]:
    """Host/device identification stamped into metrics artifacts and the
    benchmark envelope."""
    import platform

    import jax
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
    }


# ---------------------------------------------------------------------------
# Metrics artifact.
# ---------------------------------------------------------------------------
def metrics_payload(obs, name: str = "run") -> Dict[str, Any]:
    payload = {
        "schema": METRICS_SCHEMA,
        "name": name,
        "meta": dict(obs.meta),
        "host": host_meta(),
        "events": len(obs.events),
        "open_spans": obs.open_spans,
    }
    payload.update(obs.metrics.payload())
    return payload


def _artifact_dir(directory: str | None = None) -> str:
    d = directory or os.environ.get("REPRO_ARTIFACTS", "artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def save_metrics_artifact(payload: Dict[str, Any], name: str,
                          directory: str | None = None) -> str:
    """Write ``<dir>/<name>.metrics.json``; returns the path."""
    if payload.get("schema") != METRICS_SCHEMA:
        raise ValueError(f"payload schema {payload.get('schema')!r} != "
                         f"{METRICS_SCHEMA!r}")
    path = os.path.join(_artifact_dir(directory), f"{name}.metrics.json")
    with open(path, "w") as f:
        json.dump(payload, f, sort_keys=True, indent=1, allow_nan=False)
        f.write("\n")
    return path


def load_metrics_artifact(path: str) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != METRICS_SCHEMA:
        raise ValueError(f"{path}: not a {METRICS_SCHEMA} artifact "
                         f"({doc.get('schema')!r})")
    return doc


def list_metrics_artifacts(directory: str | None = None) -> List[str]:
    d = directory or os.environ.get("REPRO_ARTIFACTS", "artifacts")
    return sorted(glob.glob(os.path.join(d, "*.metrics.json")))


# ---------------------------------------------------------------------------
# JSONL event log.
# ---------------------------------------------------------------------------
def write_jsonl(obs, path: str) -> str:
    """One JSON object per line: a header record, then every span/instant
    event in emission order."""
    with open(path, "w") as f:
        f.write(json.dumps({"schema": f"{SCHEMA_PREFIX}/events/v1",
                            "meta": obs.meta, "host": host_meta()},
                           sort_keys=True) + "\n")
        for ev in obs.events:
            f.write(json.dumps(ev, sort_keys=True) + "\n")
    return path


# ---------------------------------------------------------------------------
# Chrome / Perfetto trace.
# ---------------------------------------------------------------------------
def _tid(ev: Dict[str, Any]) -> int:
    """Track assignment: sweep cells get their own rows, everything else
    shares track 0."""
    cell = ev.get("tags", {}).get("cell")
    return int(cell) + 1 if cell is not None else 0


def perfetto_payload(obs) -> Dict[str, Any]:
    """Chrome trace-event JSON (the `trace.json` flavor Perfetto's UI and
    `chrome://tracing` both load): complete ("X") events for spans —
    closed by construction — and instant ("i") events for the rest, all
    timestamps in microseconds from the tracer epoch."""
    events = []
    for ev in obs.events:
        args = {str(k): v for k, v in ev.get("tags", {}).items()}
        if "stage" in ev:
            args["stage"] = ev["stage"]
        rec = {"name": ev["name"], "ph": ev["ph"], "cat": "repro",
               "ts": ev["ts"] * 1e6, "pid": 0, "tid": _tid(ev),
               "args": args}
        if ev["ph"] == "X":
            rec["dur"] = ev["dur"] * 1e6
        else:
            rec["s"] = "t"
        events.append(rec)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"schema": f"{SCHEMA_PREFIX}/trace/v1",
                          **{str(k): str(v) for k, v in obs.meta.items()}}}


def write_trace(obs, path: str) -> str:
    if obs.open_spans:
        raise ValueError(f"{obs.open_spans} span(s) still open — export "
                         "traces only between rounds / after train()")
    with open(path, "w") as f:
        json.dump(perfetto_payload(obs), f)
        f.write("\n")
    return path

"""Metrics registry: counters, gauges and distributions with string tags.

Everything here is host-side bookkeeping over values the pipeline already
computed — recording a metric never launches device work, never draws
randomness, and never forces a sync (spans own the fencing policy). Keys
are ``(name, sorted(tags))`` so the same metric under different tags (e.g.
``span/round/plan{stage=compile}`` vs ``{stage=execute}``) accumulates
separately.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

_Key = Tuple[str, Tuple[Tuple[str, Any], ...]]


def _key(name: str, tags: Dict[str, Any] | None) -> _Key:
    if not tags:
        return (name, ())
    return (name, tuple(sorted(tags.items())))


class MetricsRegistry:
    """Counters (monotonic sums), gauges (last value wins) and
    distributions (n / sum / min / max)."""

    def __init__(self):
        self._counters: Dict[_Key, float] = {}
        self._gauges: Dict[_Key, float] = {}
        self._dists: Dict[_Key, List[float]] = {}   # [n, sum, min, max]

    # ------------------------------------------------------------------
    def count(self, name: str, value: float = 1, **tags) -> None:
        k = _key(name, tags)
        self._counters[k] = self._counters.get(k, 0) + value

    def gauge(self, name: str, value: float, **tags) -> None:
        self._gauges[_key(name, tags)] = value

    def observe(self, name: str, value: float, **tags) -> None:
        k = _key(name, tags)
        d = self._dists.get(k)
        if d is None:
            self._dists[k] = [1, value, value, value]
        else:
            d[0] += 1
            d[1] += value
            d[2] = min(d[2], value)
            d[3] = max(d[3], value)

    # ------------------------------------------------------------------
    def counter_value(self, name: str, **tags) -> float:
        return self._counters.get(_key(name, tags), 0)

    def gauge_value(self, name: str, default: float | None = None,
                    **tags) -> float | None:
        return self._gauges.get(_key(name, tags), default)

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold another registry in (counters add, gauges overwrite,
        distributions pool). Used when aggregating per-process benches."""
        for k, v in other._counters.items():
            self._counters[k] = self._counters.get(k, 0) + v
        self._gauges.update(other._gauges)
        for k, d in other._dists.items():
            mine = self._dists.get(k)
            if mine is None:
                self._dists[k] = list(d)
            else:
                mine[0] += d[0]
                mine[1] += d[1]
                mine[2] = min(mine[2], d[2])
                mine[3] = max(mine[3], d[3])

    # ------------------------------------------------------------------
    @staticmethod
    def _rows(table: Dict[_Key, Any], render) -> List[Dict[str, Any]]:
        rows = []
        for (name, tags) in sorted(table):
            rows.append({"name": name, "tags": dict(tags),
                         **render(table[(name, tags)])})
        return rows

    def payload(self) -> Dict[str, Any]:
        """JSON-ready snapshot (sorted, scalar leaves)."""
        return {
            "counters": self._rows(self._counters,
                                   lambda v: {"value": v}),
            "gauges": self._rows(self._gauges, lambda v: {"value": v}),
            "dists": self._rows(self._dists,
                                lambda d: {"n": d[0], "sum": d[1],
                                           "min": d[2], "max": d[3]}),
        }

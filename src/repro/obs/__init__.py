"""repro.obs — structured tracing, metrics, and trace export for the GenFV
round pipeline.

Three pieces (DESIGN.md §Observability):

* `trace.Obs` — a span/event tracer with an explicit injectable clock and
  JIT-aware timing: spans fence with `jax.block_until_ready` only at span
  boundaries (via `Span.sync`) and tag the first call through each
  (name, key) pair as ``stage="compile"`` vs steady-state ``"execute"``.
* `metrics.MetricsRegistry` — counters / gauges / distributions fed by what
  the pipeline already computes (planner convergence, bucket padding waste,
  the fault ledger, realized-vs-planned round delay, sweep cache hits).
* `sinks` — a JSONL event log, a Chrome/Perfetto ``trace.json`` exporter,
  and the versioned ``repro.obs/metrics/v1`` artifact written alongside the
  `repro.exp` outputs under ``artifacts/``.

The hard invariant: the disabled path (`NULL_OBS`) is a no-op that never
touches RNG streams or jitted programs, and the ENABLED path only reads —
so runs with obs on and off are bitwise-identical (tests/test_obs.py pins
this on both planner backends, with and without fault injection).
"""
from repro.obs.metrics import MetricsRegistry
from repro.obs.sinks import (BENCH_SCHEMA, METRICS_SCHEMA, host_meta,
                             list_metrics_artifacts, load_metrics_artifact,
                             save_metrics_artifact)
from repro.obs.trace import (NULL_OBS, NullObs, Obs, ProgressLogger, Span,
                             Stopwatch, VirtualClock, log_line, stopwatch)

__all__ = [
    "BENCH_SCHEMA", "METRICS_SCHEMA", "MetricsRegistry", "NULL_OBS",
    "NullObs", "Obs", "ProgressLogger", "Span", "Stopwatch", "VirtualClock",
    "host_meta", "list_metrics_artifacts", "load_metrics_artifact",
    "log_line", "save_metrics_artifact", "stopwatch",
]

"""Persistent vectorized vehicular world (paper Sec. V-A2 made stateful).

The seed redrew an i.i.d. fleet from scratch every round
(`core/mobility.py::sample_fleet`): no vehicle persisted between rounds, no
one ever left coverage mid-round, and the channel was memoryless — the
velocity-aware SUBP1 selection policy was never actually stressed. This
module keeps a struct-of-arrays world that the FL runner steps once per
round:

* **Arrivals** — Poisson process at the two coverage edges (eastbound
  vehicles enter at x=-sqrt(r^2-e^2), westbound at +sqrt(r^2-e^2)), with
  the entry jitter spread over the step so a long step does not pile
  arrivals on the boundary.
* **Departures** — a vehicle whose position exits the coverage chord is
  removed and releases its data-partition binding.
* **Speeds** — eq. 24 road-load feedback: the per-step target speed is
  v_bar(M) for the *current* on-road count M (bound and unbound vehicles
  alike congest the road), and individual speeds follow an AR(1) pull
  toward it with the truncated-normal noise of the memoryless model.
* **Shadowing** — per-vehicle AR(1) log-normal shadowing (dB domain) with
  stationary std `cfg.shadow_sigma_db` and decorrelation time
  `cfg.shadow_corr_time`, so SNR evolves coherently with distance between
  rounds instead of being redrawn.
* **Data binding** — each vehicle holds at most one Dirichlet data
  partition for its whole residency; arrivals draw a random free partition
  (blocked arrivals stay on the road as pure traffic), departures return
  theirs to the pool.

All state lives in flat numpy arrays and every update is vectorized, so a
world step is O(N) numpy work with no per-vehicle Python in the hot loop —
`benchmarks/bench_world.py` drives it at 10k-100k vehicles. RNG is consumed
in a FIXED order per step (speed noise -> shadowing noise -> arrival count
-> arrival attributes -> partition draws); the determinism guard in
tests/test_sim.py relies on this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import mobility
from repro.core.emd import emd_many
from repro.core.mobility import Vehicle
from repro.sim.scenarios import Scenario


@dataclass
class WorldStats:
    """Cumulative counters since world construction."""
    time: float = 0.0            # simulated seconds
    steps: int = 0
    arrivals: int = 0
    departures: int = 0
    blocked_arrivals: int = 0    # arrived with no free data partition


@dataclass
class WorldState:
    """Struct-of-arrays snapshot of the live fleet (all arrays [N])."""
    vid: np.ndarray        # int64 persistent vehicle ids
    x: np.ndarray          # signed position along the road (m), 0 = RSU foot
    v: np.ndarray          # signed speed (km/h); sign = direction
    phi_max: np.ndarray    # max uplink tx power (W)
    f_mem: np.ndarray      # GPU memory frequency (Hz)
    f_core: np.ndarray     # GPU core frequency (Hz)
    v_core: np.ndarray     # GPU core voltage (V)
    shadow_db: np.ndarray  # AR(1) shadowing state on h0 (dB)
    partition: np.ndarray  # int64 bound data-partition index, -1 = unbound

    @property
    def n(self) -> int:
        return len(self.x)


class VehicularWorld:
    """The persistent world. `step(rng, dt)` advances it; `fleet(...)` views
    the data-bound vehicles as `core.mobility.Vehicle`s for SUBP1-4."""

    def __init__(self, cfg: GenFVConfig, scenario: Scenario,
                 n_partitions: int, rng: np.random.Generator):
        self.cfg = cfg
        self.scenario = scenario
        self.n_partitions = int(n_partitions)
        self.stats = WorldStats()
        self._next_vid = 0
        self._hists_src = None   # per-partition histogram/EMD cache, keyed
        self._hists64 = None     # on the hists object identity (fleet())
        self._emds = None

        half = mobility.coverage_half_length(cfg)
        mean0 = scenario.init_mean if scenario.init_mean is not None \
            else cfg.num_vehicles
        n0 = max(int(rng.poisson(mean0)), 1)
        x = rng.uniform(-half, half, size=n0)
        dirs = np.where(rng.random(n0) < scenario.direction_split, 1.0, -1.0)
        speeds = mobility.sample_speeds(rng, cfg, n0, m_on_road=n0)
        caps = self._draw_capabilities(rng, n0)
        shadow = rng.normal(0.0, cfg.shadow_sigma_db, size=n0)
        # initial binding: a random subset of partitions, one per vehicle
        perm = rng.permutation(self.n_partitions)
        nb = min(n0, self.n_partitions)
        part = np.full(n0, -1, np.int64)
        part[:nb] = perm[:nb]
        self._free: List[int] = [int(p) for p in perm[nb:]]

        self.state = WorldState(
            vid=np.arange(n0, dtype=np.int64), x=x, v=speeds * dirs,
            phi_max=caps[0], f_mem=caps[1], f_core=caps[2], v_core=caps[3],
            shadow_db=shadow, partition=part)
        self._next_vid = n0

    # ------------------------------------------------------------------
    def _draw_capabilities(self, rng: np.random.Generator, n: int):
        s, cfg = self.scenario, self.cfg
        return (rng.uniform(cfg.phi_min, cfg.phi_max, size=n),
                rng.uniform(*s.gpu_f_mem, size=n),
                rng.uniform(*s.gpu_f_core, size=n),
                rng.uniform(*s.gpu_v_core, size=n))

    # ------------------------------------------------------------------
    def step(self, rng: np.random.Generator, dt: float) -> None:
        """Advance the world by `dt` seconds (one FL round).

        RNG consumption order is fixed: (1) speed innovations, (2) shadowing
        innovations for survivors, (3) arrival count, (4) arrival attributes,
        (5) one partition draw per bindable arrival.
        """
        cfg, scn, st = self.cfg, self.scenario, self.state
        half = mobility.coverage_half_length(cfg)
        n = st.n

        # (1) eq.-24 road-load speed feedback + AR(1) individual speeds
        v_bar = mobility.average_speed(cfg, n)
        sigma = cfg.sigma_k * v_bar
        rho_v = float(np.clip(scn.speed_corr, 0.0, 1.0))
        eps_v = rng.normal(size=n)
        speed = np.abs(st.v)
        speed = (rho_v * speed + (1.0 - rho_v) * v_bar
                 + sigma * np.sqrt(1.0 - rho_v ** 2) * eps_v)
        speed = np.clip(speed, cfg.v_min, cfg.v_max)
        sign = np.where(st.v >= 0.0, 1.0, -1.0)
        v = sign * speed

        # positions advance, then out-of-chord vehicles depart
        x = st.x + v / 3.6 * dt
        keep = np.abs(x) <= half
        gone = np.flatnonzero(~keep)
        if gone.size:
            released = st.partition[gone]
            self._free.extend(int(p) for p in released if p >= 0)
            self.stats.departures += int(gone.size)
        vid = st.vid[keep]
        x, v = x[keep], v[keep]
        phi, fm = st.phi_max[keep], st.f_mem[keep]
        fc, vc = st.f_core[keep], st.v_core[keep]
        part = st.partition[keep]

        # (2) AR(1) shadowing for survivors (stationary N(0, sigma_db^2))
        shadow = st.shadow_db[keep]
        if cfg.shadow_corr_time > 0.0:
            rho_s = float(np.exp(-dt / cfg.shadow_corr_time))
        else:
            rho_s = 0.0
        eps_s = rng.normal(size=len(shadow))
        shadow = (rho_s * shadow
                  + cfg.shadow_sigma_db * np.sqrt(1.0 - rho_s ** 2) * eps_s)

        # (3-5) Poisson arrivals at the coverage edges
        k = int(rng.poisson(cfg.arrival_rate * dt))
        if k > 0:
            dirs = np.where(rng.random(k) < scn.direction_split, 1.0, -1.0)
            u = rng.uniform(0.0, 1.0, size=k)   # fraction of dt already in
            sp = mobility.sample_speeds(rng, cfg, k, m_on_road=len(x) + k)
            v_new = sp * dirs
            x_new = np.clip(-dirs * half + v_new / 3.6 * dt * u, -half, half)
            caps = self._draw_capabilities(rng, k)
            sh_new = rng.normal(0.0, cfg.shadow_sigma_db, size=k)
            # only the first min(k, |free|) arrivals can bind (pops only
            # shrink the pool), so the loop — and its rng draws — stop there
            p_new = np.full(k, -1, np.int64)
            nb = min(k, len(self._free))
            for i in range(nb):
                j = int(rng.integers(len(self._free)))
                p_new[i] = self._free.pop(j)
            self.stats.blocked_arrivals += k - nb
            vid = np.concatenate(
                [vid, np.arange(self._next_vid, self._next_vid + k,
                                dtype=np.int64)])
            self._next_vid += k
            x = np.concatenate([x, x_new])
            v = np.concatenate([v, v_new])
            phi = np.concatenate([phi, caps[0]])
            fm = np.concatenate([fm, caps[1]])
            fc = np.concatenate([fc, caps[2]])
            vc = np.concatenate([vc, caps[3]])
            shadow = np.concatenate([shadow, sh_new])
            part = np.concatenate([part, p_new])
            self.stats.arrivals += k

        self.state = WorldState(vid=vid, x=x, v=v, phi_max=phi, f_mem=fm,
                                f_core=fc, v_core=vc, shadow_db=shadow,
                                partition=part)
        self.stats.time += float(dt)
        self.stats.steps += 1

    # ------------------------------------------------------------------
    def remove(self, vids: Sequence[int]) -> int:
        """Force-remove vehicles by id (fault-injected mid-round departures,
        fl/faults.py): they leave coverage immediately, releasing their data
        partitions exactly like a natural chord exit. Draws no RNG, so the
        subsequent `step` consumes the stream identically whether or not a
        removal happened. Returns the number actually removed (ids already
        gone are ignored)."""
        if len(vids) == 0:
            return 0
        st = self.state
        drop = np.isin(st.vid, np.asarray(list(vids), np.int64))
        gone = np.flatnonzero(drop)
        if gone.size == 0:
            return 0
        released = st.partition[gone]
        self._free.extend(int(p) for p in released if p >= 0)
        self.stats.departures += int(gone.size)
        keep = ~drop
        self.state = WorldState(
            vid=st.vid[keep], x=st.x[keep], v=st.v[keep],
            phi_max=st.phi_max[keep], f_mem=st.f_mem[keep],
            f_core=st.f_core[keep], v_core=st.v_core[keep],
            shadow_db=st.shadow_db[keep], partition=st.partition[keep])
        return int(gone.size)

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        """Live vehicles on the road (bound + unbound)."""
        return self.state.n

    @property
    def n_bound(self) -> int:
        """Vehicles holding a data partition (the potential FL clients)."""
        return int(np.sum(self.state.partition >= 0))

    def observe(self, obs) -> None:
        """Push the world's cumulative stats — tracked since construction
        but previously never surfaced — into a `repro.obs` registry. Reads
        only; never touches the rng or the arrays."""
        obs.gauge("world/population", self.n)
        obs.gauge("world/bound", self.n_bound)
        obs.gauge("world/time_s", self.stats.time)
        obs.gauge("world/arrivals", self.stats.arrivals)
        obs.gauge("world/departures", self.stats.departures)
        obs.gauge("world/blocked_arrivals", self.stats.blocked_arrivals)

    # ------------------------------------------------------------------
    def fleet(self, hists: Sequence[np.ndarray], sizes: Sequence[int]
              ) -> Tuple[List[Vehicle], np.ndarray]:
        """View the data-bound vehicles as `Vehicle`s for selection/planning.

        Returns (fleet, parts) where parts[j] is the data-partition index of
        fleet[j] — the runner uses it to fetch the vehicle's local dataset.
        """
        st = self.state
        bound = np.flatnonzero(st.partition >= 0)
        parts = st.partition[bound]
        # partitions are static for the runner's lifetime: normalize the
        # histograms and take their EMDs (core/emd.py, eq. 3) once per
        # distinct hists object (identity-keyed, so swapped-in data of the
        # same length cannot serve stale EMDs)
        if self._hists_src is not hists:
            self._hists_src = hists
            self._hists64 = [np.asarray(h, np.float64) for h in hists]
            self._emds = (emd_many(np.stack(self._hists64))
                          if self._hists64 else np.zeros(0))
        fleet: List[Vehicle] = []
        for i, p in zip(bound, parts):
            fleet.append(Vehicle(
                vid=int(st.vid[i]),
                x=float(st.x[i]),
                v=float(st.v[i]),
                phi_max=float(st.phi_max[i]),
                f_mem=float(st.f_mem[i]),
                f_core=float(st.f_core[i]),
                v_core=float(st.v_core[i]),
                data_size=int(sizes[p]),
                hist=self._hists64[p],
                emd=float(self._emds[p]),
                gain_db=float(st.shadow_db[i]),
            ))
        return fleet, parts

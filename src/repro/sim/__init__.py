"""repro.sim — persistent vehicular world simulator.

world       struct-of-arrays VehicularWorld: Poisson arrivals/departures,
            eq.-24 road-load speed feedback, AR(1) log-normal shadowing,
            persistent data-partition binding
scenarios   named traffic presets + registry (RunConfig.scenario)
"""
from repro.sim.scenarios import (LEGACY, SCENARIOS, Scenario, get_scenario,
                                 register, scenario_names)
from repro.sim.world import VehicularWorld, WorldState, WorldStats

"""Scenario registry for the persistent vehicular world (repro.sim).

A `Scenario` bundles (a) world-dynamics parameters that live outside
`GenFVConfig` — arrival direction split, AR(1) speed persistence, initial
population, per-vehicle GPU capability ranges — and (b) optional overrides
of the physical-layer fields in `GenFVConfig` (speed law, coverage
geometry, arrival rate, shadowing). `Scenario.apply(cfg)` returns the
overridden config; `VehicularWorld` reads both.

Named presets span the traffic regimes the selection policy has to survive:
free-flow highway, congested rush hour, choppy urban stop-and-go, a
single-direction platoon, and a sparse rural cell. `RunConfig.scenario`
picks one by name; the sentinel name ``"legacy"`` (`LEGACY`) bypasses the
world entirely and keeps the memoryless per-round sampler
(`core/mobility.py::sample_fleet`, including this PR's eq.-24 road-load
fix — the golden test in tests/test_sim.py pins its statistics).

Register custom scenarios with `register(Scenario(...))`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs.base import GenFVConfig

#: RunConfig.scenario sentinel: the seed's i.i.d. per-round fleet sampler.
LEGACY = "legacy"

# Scenario fields that override the same-named GenFVConfig fields when set.
_CFG_OVERRIDES = ("v_max", "v_min", "m_max", "sigma_k", "rsu_radius",
                  "rsu_road_offset", "arrival_rate", "shadow_sigma_db",
                  "shadow_corr_time")


@dataclass(frozen=True)
class Scenario:
    name: str
    description: str = ""
    # --- world dynamics (consumed by VehicularWorld directly) -------------
    direction_split: float = 0.5      # P(eastbound) for arrivals
    speed_corr: float = 0.9           # AR(1) rho of individual speed per step
    init_mean: Optional[float] = None  # initial Poisson mean (None -> cfg)
    gpu_f_mem: Tuple[float, float] = (1.25e9, 1.75e9)
    gpu_f_core: Tuple[float, float] = (1.0e9, 1.6e9)
    gpu_v_core: Tuple[float, float] = (0.8, 1.1)
    # --- GenFVConfig overrides (None = keep the config's value) -----------
    v_max: Optional[float] = None
    v_min: Optional[float] = None
    m_max: Optional[int] = None
    sigma_k: Optional[float] = None
    rsu_radius: Optional[float] = None
    rsu_road_offset: Optional[float] = None
    arrival_rate: Optional[float] = None
    shadow_sigma_db: Optional[float] = None
    shadow_corr_time: Optional[float] = None

    def apply(self, cfg: GenFVConfig) -> GenFVConfig:
        """Overlay this scenario's physical-layer overrides onto `cfg`."""
        overrides = {k: getattr(self, k) for k in _CFG_OVERRIDES
                     if getattr(self, k) is not None}
        return dataclasses.replace(cfg, **overrides) if overrides else cfg


SCENARIOS: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (name collisions overwrite)."""
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        known = ", ".join(sorted(SCENARIOS))
        raise KeyError(
            f"unknown scenario {name!r}; registered: {known} "
            f"(or {LEGACY!r} for the memoryless seed sampler)") from None


def scenario_names() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


# ---------------------------------------------------------------------------
# Presets. Geometry defaults to the paper cell (r=500 m chord ~ 1 km) unless
# overridden; arrival rates are picked so the equilibrium population
# (arrival_rate x chord/speed residency) lands in the named regime.
# ---------------------------------------------------------------------------
register(Scenario(
    name="highway_free_flow",
    description="uncongested highway: fast, steady, mild shadowing",
    speed_corr=0.95,
    arrival_rate=1.1, v_max=120.0, v_min=10.0, m_max=160, sigma_k=0.1,
    shadow_sigma_db=3.0, shadow_corr_time=30.0,
))

register(Scenario(
    name="rush_hour",
    description="over-capacity road: eq.-24 congestion collapses speeds; "
                "deep, fast-moving shadowing from dense traffic",
    speed_corr=0.85, init_mean=80.0,
    arrival_rate=3.0, v_max=120.0, v_min=10.0, m_max=60, sigma_k=0.15,
    shadow_sigma_db=6.0, shadow_corr_time=10.0,
))

register(Scenario(
    name="urban_stop_go",
    description="small urban cell: slow choppy speeds, strong short-memory "
                "shadowing from buildings",
    speed_corr=0.5, init_mean=30.0,
    arrival_rate=1.5, v_max=50.0, v_min=5.0, m_max=50, sigma_k=0.35,
    rsu_radius=300.0, rsu_road_offset=15.0,
    shadow_sigma_db=8.0, shadow_corr_time=5.0,
))

register(Scenario(
    name="platoon",
    description="single-direction convoy: tight speed spread, long-memory "
                "channel, everyone exits together",
    direction_split=1.0, speed_corr=0.99, init_mean=25.0,
    arrival_rate=0.8, v_max=100.0, v_min=70.0, m_max=400, sigma_k=0.03,
    shadow_sigma_db=2.0, shadow_corr_time=60.0,
))

register(Scenario(
    name="sparse_rural",
    description="big empty cell: few vehicles, fast, strong slow-fading "
                "shadowing over a long chord",
    speed_corr=0.9, init_mean=8.0,
    arrival_rate=0.15, v_max=110.0, v_min=30.0, m_max=400, sigma_k=0.12,
    rsu_radius=800.0,
    shadow_sigma_db=5.0, shadow_corr_time=40.0,
))

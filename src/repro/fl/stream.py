"""Event-driven streaming RSU rounds (ROADMAP direction 5: the
continuously-running, failure-tolerant round loop).

The synchronous `GenFVRunner` blocks every round on its slowest selected
vehicle's eq.-6/eq.-10 delay. `StreamEngine` instead runs GenFV rounds
against a deterministic **virtual wall-clock** (`repro.obs.VirtualClock` —
never the host wall clock; tests/test_obs.py lints the package for it): each
selected vehicle's upload completes at its `realized_arrivals(...)` instant
on a seeded event queue, and the round **commits when a configurable quorum
of updates has arrived or the round deadline expires**, whichever first.

Semantics per round, all driven through the shared
`GenFVRunner._execute_round` body so the two loops cannot drift:

* **Quorum commit** — with K selected and quorum q in (0, 1], the round
  commits at the ceil(q*K)-th eligible arrival if that lands within the
  planned straggler window t_bar. Updates arriving after the commit are
  NOT discarded: they enter the in-flight queue with their realized due
  times and merge on arrival (below).
* **Retry/backoff** — an outage is a FAILED upload attempt: the vehicle
  backs off min(retry_backoff_s * 2^a, retry_backoff_cap_s) and re-prices
  the attempt through eq.-10 at its refreshed channel gain
  (`fl/faults.py::realized_arrivals`), up to `retry_budget` attempts. An
  exhausted vehicle's update can never arrive; it counts as dropped
  without consuming RNG. A departed vehicle's retry is never scheduled.
* **Degradation ladder** — when quorum misses the planned window the RSU
  degrades instead of stalling, each rung ledgered in `StreamLog.rung`:
  rung 1 extends the deadline once by `deadline_slack` (only if stragglers
  are actually still inbound); rung 2 commits the partial quorum with the
  survivor weights renormalized by the same joint-normalization the
  synchronous recovery dispatch uses; rung 3 skips the merge entirely and
  carries the global forward. Rung 0 is the healthy quorum-in-window
  commit.
* **Merge-on-arrival** — an in-flight update due inside the committing
  round's window folds into that round's aggregation with the
  rho·gamma^age staleness discount (bounded-staleness regime of
  arXiv:2401.09656), exactly like the synchronous stale merge; one due in
  the gap BEFORE a round starts is absorbed immediately into the global
  (`GenFVServer.absorb`) with weight rho·gamma^age. Entries aged past
  `max_staleness` are dropped and counted (`stale_dropped`).

Determinism: the virtual clock, the round-keyed fault/retry streams
(`SeedSequence((seed, round[, RTRY]))`), and the (due, seq)-ordered event
queue make the whole schedule a pure function of (RunConfig, StreamConfig)
— same (seed, schedule) gives identical event order, commit sequence and
final params on both planner backends, and checkpoints resume mid-stream
bitwise (in-flight uploads and the clock persist in the
`repro.fl/runner-ckpt/v4` layout under a `stream` block).

Parity: with quorum=1.0, cadence 0 and no faults every rung-0 commit lands
exactly on t_bar and `StreamEngine.run` is bitwise-equal to
`GenFVRunner.train` (tests/test_stream.py pins it).
"""
from __future__ import annotations

import bisect
import dataclasses
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import read_manifest, restore_tree, save_tree
from repro.configs.base import StreamConfig
from repro.core.selection import dropout_mask
from repro.fl.faults import realized_arrivals
from repro.fl.rounds import (GenFVRunner, RoundLog, RunResult, run_payload)
from repro.obs import VirtualClock, log_line

__all__ = ["InFlight", "StreamEngine", "StreamLog"]


@dataclass
class InFlight:
    """One late upload traveling toward the RSU: enqueued by the committing
    round's `late_sink`, delivered (gap-absorb or window-merge) when the
    virtual clock passes `due`. `seq` breaks due-time ties deterministically
    (enqueue order), so the event queue is totally ordered."""
    due: float              # absolute virtual-clock arrival instant
    seq: int                # tie-break: global enqueue counter
    vid: int                # vehicle id (diagnostics)
    round: int              # round whose global the update descended from
    size: int               # |D_n|
    emd: float              # EMD_n
    rho: float              # data weight within its origin round
    retries: int            # backoff attempts consumed en route
    params: object          # the trained client model (pytree)

    def __lt__(self, other: "InFlight") -> bool:
        return (self.due, self.seq) < (other.due, other.seq)


@dataclass
class StreamLog:
    """Per-round streaming ledger, alongside the runner's `RoundLog`."""
    round: int
    t_start: float          # virtual clock at round start
    t_commit: float         # absolute commit instant
    rung: int               # degradation ladder: 0 healthy .. 3 skipped
    quorum_target: int      # ceil(quorum * K)
    arrived: int            # eligible uploads in by the commit
    merged_inflight: int    # in-flight updates folded into this commit
    gap_merged: int         # in-flight updates absorbed before round start
    stale_dropped: int      # in-flight updates aged past max_staleness
    late: int               # this round's uploads still in flight at commit
    retries: int            # backoff attempts consumed this round
    exhausted: int          # uploads whose retry budget ran out


class StreamEngine:
    """Asynchronous streaming driver over a `GenFVRunner`.

    Composes rather than subclasses: `begin_round`/`plan` are reused
    verbatim and execution goes through the runner's `_execute_round` with
    the late/skip partition and stale-merge set computed from the event
    simulation — the synchronous loop stays the semantic (and, at
    quorum=1.0 without faults, bitwise) reference.
    """

    def __init__(self, runner: GenFVRunner,
                 stream: StreamConfig | None = None,
                 clock: VirtualClock | None = None):
        run = runner.run
        if not run.vectorized:
            raise ValueError(
                "StreamEngine requires vectorized=True (the sequential "
                "reference path stays synchronous-only)")
        if run.strategy == "aigc_only":
            raise ValueError(
                "strategy='aigc_only' has no vehicle uploads to stream")
        self.runner = runner
        # explicit arg > RunConfig.stream > defaults (which reproduce the
        # synchronous semantics: full quorum, no cadence)
        self.scfg = stream if stream is not None else (
            run.stream if run.stream is not None else StreamConfig())
        self.clock = clock if clock is not None else VirtualClock()
        self.obs = runner.obs
        self.inflight: List[InFlight] = []   # kept sorted by (due, seq)
        self._seq = 0
        self.slogs: List[StreamLog] = []

    @property
    def now(self) -> float:
        return self.clock()

    # ------------------------------------------------------------------
    def _absorb_gap(self, t: int, t0: float) -> tuple:
        """Deliver every in-flight update due by `t0` (the round start):
        merge-on-arrival into the global with weight rho·gamma^age, or drop
        (counted) past max_staleness."""
        scfg = self.scfg
        server = self.runner.server
        merged = dropped = 0
        while self.inflight and self.inflight[0].due <= t0:
            e = self.inflight.pop(0)
            age = t - e.round
            if age > scfg.max_staleness:
                dropped += 1
                continue
            w = e.rho * scfg.staleness_discount ** age
            with self.obs.span("stream/arrival", round=t, vid=e.vid,
                               src=e.round, gap=1) as sp:
                sp.sync = server.absorb(e.params, w)
            merged += 1
        return merged, dropped

    def _commit_schedule(self, k: int, times: np.ndarray,
                         eligible: np.ndarray, t_bar: float) -> tuple:
        """The quorum/deadline decision: returns (rung, commit offset).

        Rung 0: the q-th eligible arrival lands within the planned window
        t_bar. Rung 1: quorum still completes within the slack-extended
        deadline (the one extension the ladder allows). Rung 2: quorum is
        unreachable — commit whatever arrived by the horizon (the extended
        deadline if stragglers were genuinely still inbound, else t_bar:
        waiting can't help when every missing upload is permanently gone).
        Rung 3: nothing arrived at all; skip the merge, carry the global."""
        scfg = self.scfg
        q = max(1, int(np.ceil(scfg.quorum * k)))
        ts = np.sort(times[eligible])
        d0 = float(t_bar)
        d1 = d0 * (1.0 + scfg.deadline_slack)
        n = ts.size
        if n >= q and ts[q - 1] <= d0:
            return 0, float(ts[q - 1]), q
        if n >= q and ts[q - 1] <= d1:
            return 1, float(ts[q - 1]), q
        inbound = n > 0 and float(ts[-1]) > d0
        horizon = d1 if inbound else d0
        arrived = int(np.searchsorted(ts, horizon, side="right"))
        return (2 if arrived else 3), horizon, q

    # ------------------------------------------------------------------
    def run_round(self, t: int) -> RoundLog:
        runner = self.runner
        cfg = runner.cfg
        scfg = self.scfg
        obs = self.obs
        t0 = self.clock()

        with obs.span("stream/tick", round=t, inflight=len(self.inflight)):
            gap_merged, dropped_gap = self._absorb_gap(t, t0)
            pending = runner.begin_round(t)
            plan = runner.plan(pending)
        k = len(plan.selected)
        spec = runner.faults.spec if runner.faults is not None else None

        if k == 0:
            # empty round: no uploads, no quorum — the slot still elapses
            log = runner._execute_round(
                pending, plan, rf=None, late_mask=None,
                t_round=plan.t_bar, survive=None, stale_models=[],
                stale_weights=[], stale_emds=[], stale_dropped=dropped_gap,
                guard_host=spec is not None, dt_floor=scfg.cadence_s)
            self.clock.advance(max(cfg.t_max, scfg.cadence_s))
            self.slogs.append(StreamLog(
                t, t0, t0, 0, 0, 0, 0, gap_merged, dropped_gap, 0, 0, 0))
            self._count(self.slogs[-1])
            return log

        # ---- realized arrival schedule (retry/backoff under outages) -----
        if spec is not None:
            rf = runner.faults.draw(t, k)
            with obs.span("stream/retry", round=t,
                          outages=int(rf.outage.sum())):
                times, retries, exhausted = realized_arrivals(
                    cfg, pending.fleet, plan, runner.model_bits, rf, spec, t,
                    retry_budget=scfg.retry_budget,
                    backoff_s=scfg.retry_backoff_s,
                    backoff_cap_s=scfg.retry_backoff_cap_s)
        else:
            rf = None
            times = (np.asarray(plan.t_cp, np.float64)
                     + np.asarray(plan.t_mu, np.float64))
            retries = np.zeros(k, np.int64)
            exhausted = np.zeros(k, bool)

        # coverage dropout against the PLANNED window (the RSU admitted the
        # schedule before any commit-time is known; matches the fault-free
        # synchronous rule exactly)
        survive = None
        alive = np.ones(k, bool)
        if runner.world is not None:
            survive = np.asarray(dropout_mask(
                cfg, pending.fleet, plan.selected,
                min(plan.t_bar, cfg.t_max)), bool)
            alive = survive.copy()
        has_data = np.array(
            [len(runner.client_data[pending.parts[j]][1]) >= 2
             for j in plan.selected], bool)
        # an upload can arrive iff its vehicle stays in coverage, has data
        # to train on, and its arrival time is finite (departed/exhausted
        # uploads are inf by construction)
        eligible = alive & has_data & np.isfinite(times)

        rung, c_rel, q = self._commit_schedule(k, times, eligible, plan.t_bar)
        arrived = int((eligible & (times <= c_rel)).sum())
        late_mask = eligible & (times > c_rel)
        skip_mask = exhausted if exhausted.any() else None

        # ---- in-flight updates landing inside this round's window --------
        stale_models, stale_weights, stale_emds = [], [], []
        merged_inflight = dropped_window = 0
        commit_abs = t0 + c_rel
        while self.inflight and self.inflight[0].due <= commit_abs:
            e = self.inflight.pop(0)
            age = t - e.round
            if age > scfg.max_staleness:
                dropped_window += 1
                continue
            with obs.span("stream/arrival", round=t, vid=e.vid,
                          src=e.round, gap=0):
                stale_models.append(e.params)
                stale_weights.append(e.size * scfg.staleness_discount ** age)
                stale_emds.append(e.emd)
            merged_inflight += 1

        # late uploads re-enter the event queue at their realized instants
        s_total = float(sum(pending.fleet[j].data_size
                            for j in plan.selected)) or 1.0

        def sink(entry, pos):
            self._seq += 1
            bisect.insort(self.inflight, InFlight(
                due=t0 + float(times[pos]), seq=self._seq, vid=entry.vid,
                round=t, size=entry.size, emd=entry.emd,
                rho=entry.size / s_total, retries=int(retries[pos]),
                params=entry.params))

        with obs.span("stream/commit", round=t, rung=rung, quorum=q,
                      arrived=arrived) as sp:
            log = runner._execute_round(
                pending, plan, rf=rf, late_mask=late_mask, t_round=c_rel,
                survive=survive, stale_models=stale_models,
                stale_weights=stale_weights, stale_emds=stale_emds,
                stale_dropped=dropped_gap + dropped_window, late_sink=sink,
                skip_mask=skip_mask, guard_host=spec is not None,
                dt_floor=scfg.cadence_s)
            sp.sync = runner.server.params

        # streaming cadence floors the clock advance; t_rsu deliberately
        # does NOT extend it — RSU generation pipelines with the next
        # round's label-sharing/selection phase
        self.clock.advance(max(c_rel, scfg.cadence_s))
        slog = StreamLog(t, t0, commit_abs, rung, q, arrived,
                         merged_inflight, gap_merged,
                         dropped_gap + dropped_window,
                         int(late_mask.sum()), int(retries.sum()),
                         int(exhausted.sum()))
        self.slogs.append(slog)
        self._count(slog)
        return log

    def _count(self, s: StreamLog) -> None:
        obs = self.obs
        if not obs.enabled:
            return
        obs.count("stream/rounds", 1)
        obs.count("stream/retries", s.retries)
        obs.count("stream/exhausted", s.exhausted)
        obs.count("stream/gap_merged", s.gap_merged)
        obs.count("stream/merged_inflight", s.merged_inflight)
        obs.count("stream/stale_dropped", s.stale_dropped)
        if s.rung:
            obs.count("stream/quorum_miss", 1)
        obs.observe("stream/rung", s.rung)
        obs.gauge("stream/inflight", len(self.inflight))

    # ------------------------------------------------------------------
    def run(self, verbose: bool = False, checkpoint_path: str | None = None,
            checkpoint_every: int = 1) -> RunResult:
        """Run (or resume) the remaining rounds on the virtual clock.
        Mirrors `GenFVRunner.train`, checkpointing the streaming state
        alongside the runner's."""
        runner = self.runner
        for t in range(runner.next_round, runner.run.rounds):
            log = self.run_round(t)
            if verbose:
                s = self.slogs[-1]
                log_line(
                    self.obs, "stream/round",
                    f"[stream] round {t:3d} rung={s.rung} "
                    f"q={s.arrived}/{s.quorum_target} "
                    f"now={self.now:8.2f}s inflight={len(self.inflight)} "
                    f"acc={log.accuracy:.3f}",
                    force=t == runner.run.rounds - 1,
                    round=t, accuracy=log.accuracy)
            if checkpoint_path is not None and \
                    (t + 1) % max(checkpoint_every, 1) == 0:
                with self.obs.span("round/checkpoint", round=t):
                    self.save_checkpoint(checkpoint_path)
        return RunResult(list(runner.logs))

    # ------------------------------------------------------------------
    # Mid-stream checkpointing: the runner's v3 layout plus a `stream`
    # block (virtual clock, enqueue counter, streaming ledger, and the
    # full in-flight queue including each update's pytree). The manifest
    # carries `stream_cfg` so the synchronous loader refuses the file.
    # ------------------------------------------------------------------
    _SLOG_FLOAT_FIELDS = ("t_start", "t_commit")

    def _slogs_state(self) -> dict:
        return {f.name: np.asarray(
                    [getattr(s, f.name) for s in self.slogs],
                    np.float64 if f.name in self._SLOG_FLOAT_FIELDS
                    else np.int64)
                for f in dataclasses.fields(StreamLog)}

    def save_checkpoint(self, path: str) -> str:
        state = self.runner._checkpoint_state()
        state["stream"] = {
            "now": np.float64(self.clock()),
            "seq": np.int64(self._seq),
            "slogs": self._slogs_state(),
            "inflight": ({} if not self.inflight else {
                "due": np.asarray([e.due for e in self.inflight],
                                  np.float64),
                "seq": np.asarray([e.seq for e in self.inflight], np.int64),
                "vid": np.asarray([e.vid for e in self.inflight], np.int64),
                "round": np.asarray([e.round for e in self.inflight],
                                    np.int64),
                "size": np.asarray([e.size for e in self.inflight],
                                   np.int64),
                "emd": np.asarray([e.emd for e in self.inflight],
                                  np.float64),
                "rho": np.asarray([e.rho for e in self.inflight],
                                  np.float64),
                "retries": np.asarray([e.retries for e in self.inflight],
                                      np.int64),
                "params": [e.params for e in self.inflight],
            }),
        }
        meta = {"schema": self.runner.CKPT_SCHEMA,
                "run": run_payload(self.runner.run),
                "stream_cfg": self.scfg.to_payload()}
        return save_tree(path, state, metadata=meta)

    def load_checkpoint(self, path: str) -> int:
        """Restore a streaming snapshot into this (freshly constructed,
        identically configured) engine; returns the next round to run."""
        meta = read_manifest(path)["metadata"]
        self.runner._check_manifest(meta)
        if "stream_cfg" not in meta:
            raise ValueError(
                "checkpoint was written by the synchronous runner (no "
                "in-flight state); load it with GenFVRunner.load_checkpoint")
        if meta["stream_cfg"] != self.scfg.to_payload():
            raise ValueError(
                "checkpoint was written under a different streaming policy: "
                f"{meta['stream_cfg']} vs {self.scfg.to_payload()}")
        state = restore_tree(path)
        self.runner._restore_state(state)
        s = state["stream"]
        self.clock.t = float(s["now"])
        self._seq = int(s["seq"])
        slogs = s["slogs"]
        names = [f.name for f in dataclasses.fields(StreamLog)]
        self.slogs = [
            StreamLog(**{n: (float(slogs[n][i])
                             if n in self._SLOG_FLOAT_FIELDS
                             else int(slogs[n][i])) for n in names})
            for i in range(len(slogs["round"]))] if slogs else []
        inf = s["inflight"]
        self.inflight = []
        if inf:
            for i in range(len(inf["seq"])):
                self.inflight.append(InFlight(
                    due=float(inf["due"][i]), seq=int(inf["seq"][i]),
                    vid=int(inf["vid"][i]), round=int(inf["round"][i]),
                    size=int(inf["size"][i]), emd=float(inf["emd"][i]),
                    rho=float(inf["rho"][i]),
                    retries=int(inf["retries"][i]),
                    params=jax.tree.map(jnp.asarray, inf["params"][i])))
        return self.runner.next_round

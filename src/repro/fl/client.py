"""Vehicle-side local training (paper Sec. III-C1): h mini-batch SGD steps
from the distributed global model. Also implements the FedProx proximal
variant [18] used as an extra baseline (paper Sec. II)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import cnn_loss


def local_sgd_steps(params, cfg, batches_imgs, batches_labels, h: int,
                    lr: float, prox_mu: float = 0.0):
    """h SGD steps over stacked batches (imgs [h,B,H,W,C], labels [h,B]).

    prox_mu > 0 adds FedProx's proximal term mu/2 ||w - w_global||^2 anchored
    at the incoming global model.

    Un-jitted body shared by the jitted per-vehicle `local_sgd` (sequential
    reference path) and the vmapped fleet engine (fl/fleet.py), so both paths
    trace the exact same math."""
    anchor = params

    def step(p, imgs, labels):
        def obj(pp):
            loss = cnn_loss(pp, cfg, {"images": imgs, "labels": labels})[0]
            if prox_mu > 0.0:
                sq = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                    jax.tree.leaves(pp), jax.tree.leaves(anchor)))
                loss = loss + 0.5 * prox_mu * sq
            return loss
        loss, grads = jax.value_and_grad(obj)(p)
        p = jax.tree.map(lambda w, g: w - lr * g, p, grads)
        return p, loss

    # unrolled python loop: XLA:CPU runs scan bodies ~30x slower than the
    # equivalent unrolled HLO (h is small and static, so unrolling is cheap)
    losses = []
    for i in range(h):
        params, l = step(params, batches_imgs[i], batches_labels[i])
        losses.append(l)
    return params, jnp.stack(losses)


local_sgd = partial(jax.jit, static_argnums=(1, 4, 6))(local_sgd_steps)


def client_update(params, cfg, images, labels, rng: np.random.Generator,
                  h: int, batch_size: int, lr: float, prox_mu: float = 0.0):
    """Sample h local mini-batches and run local SGD. Returns (params, loss)."""
    n = len(labels)
    # fixed batch shape (sampling with replacement) so the jitted local_sgd
    # compiles once for the whole fleet
    idx = rng.integers(0, n, size=(h, batch_size))
    bi = jnp.asarray(images[idx])
    bl = jnp.asarray(labels[idx])
    new_params, losses = local_sgd(params, cfg, bi, bl, h, lr, prox_mu)
    return new_params, float(losses.mean())

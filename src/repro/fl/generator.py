"""AIGC generation services for the GenFV server.

Two implementations of the same interface `generate(labels, rng) -> images`:

* DDPMGenerator   — the real diffusion model (diffusion/ddpm.py), trained on
                    a public-style reference pool. Used in examples and the
                    diffusion tests.
* OracleGenerator — procedural sampler with a controllable *quality gap*
                    (blur + noise + pattern distortion), standing in for a
                    pre-trained foundation model at RSU scale. The gap
                    parameter reproduces the paper's observation that
                    AIGC-only models plateau below real-data models
                    (Sec. VI-C). Used by the benchmark suite for speed.

Both honour SUBP4's per-label schedule.
"""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import _class_pattern, _coarse_pattern, _fine_pattern
from repro.diffusion import DDPM, ddpm_sample


class OracleGenerator:
    """Quality gap model: the generator reproduces the coarse per-class
    'shape' faithfully but only `fine_frac` of the high-frequency per-class
    'texture' (data/synthetic.py builds real samples from 0.6*coarse +
    0.4*fine). Consequences, mirroring the paper's Fig. 10-12:
    * AIGC-only models plateau below the real-data ceiling (the weak
      texture signal limits within-pair discrimination), and
    * the generated data stays in-distribution, so the augmented model's
      weights average productively into the federated model (eq. 4) —
      a fully out-of-distribution generator makes weight blending
      destructive (observed and recorded in EXPERIMENTS.md)."""

    def __init__(self, dataset: str, fine_frac: float = 0.4,
                 noise: float = 0.30):
        self.dataset = dataset
        self.fine_frac = fine_frac
        self.noise = noise

    def generate(self, labels: np.ndarray, rng: np.random.Generator):
        n = len(labels)
        imgs = np.empty((n, 32, 32, 3), np.float32)
        shifts = rng.integers(-4, 5, size=(n, 2))
        eps = rng.normal(0, self.noise, size=imgs.shape).astype(np.float32)
        for i, c in enumerate(labels):
            p = (0.6 * _coarse_pattern(self.dataset, int(c))
                 + 0.4 * self.fine_frac * _fine_pattern(self.dataset, int(c)))
            p = np.roll(p, shifts[i], axis=(0, 1))
            imgs[i] = np.clip(0.8 * p + eps[i], -1, 1)
        return imgs


class DDPMGenerator:
    def __init__(self, params, ddpm: DDPM):
        self.params = params
        self.ddpm = ddpm

    def generate(self, labels: np.ndarray, rng: np.random.Generator):
        import jax
        key = jax.random.PRNGKey(int(rng.integers(0, 2 ** 31)))
        return np.asarray(ddpm_sample(self.params, self.ddpm, key, labels))

"""AIGC generation services for the GenFV server.

Implementations of the same interface
`generate(labels, rng, round_idx=0) -> images` (the server passes
`round_idx` only to generators that accept it, so bare two-arg generators
keep working):

* DDPMGenerator   — the real diffusion model (diffusion/ddpm.py), trained on
                    a public-style reference pool. Used in examples and the
                    diffusion tests.
* OracleGenerator — procedural sampler with a controllable *quality gap*
                    (blur + noise + pattern distortion), standing in for a
                    pre-trained foundation model at RSU scale. The gap
                    parameter reproduces the paper's observation that
                    AIGC-only models plateau below real-data models
                    (Sec. VI-C). Used by the benchmark suite for speed.

Both honour SUBP4's per-label schedule.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.data.synthetic import IMG, _coarse_pattern, _fine_pattern
from repro.diffusion import DDPM

#: every dataset's full class set (cifar100's 100 is the max) times a
#: handful of fine_frac variants fits; beyond that, eviction beats the
#: unbounded growth a multi-dataset sweep used to accumulate (each entry
#: is a 12 KiB [32,32,3] float32 pattern).
ORACLE_CACHE_SIZE = 512


@lru_cache(maxsize=ORACLE_CACHE_SIZE)
def _oracle_pattern(dataset: str, cls: int, fine_frac: float) -> np.ndarray:
    """Degraded per-class pattern, keyed per (dataset, class, fine_frac):
    full coarse shape, fine_frac of the texture (same float op order as the
    original per-image computation)."""
    return (0.6 * _coarse_pattern(dataset, cls)
            + (0.4 * float(fine_frac)) * _fine_pattern(dataset, cls))


class OracleGenerator:
    """Quality gap model: the generator reproduces the coarse per-class
    'shape' faithfully but only `fine_frac` of the high-frequency per-class
    'texture' (data/synthetic.py builds real samples from 0.6*coarse +
    0.4*fine). Consequences, mirroring the paper's Fig. 10-12:
    * AIGC-only models plateau below the real-data ceiling (the weak
      texture signal limits within-pair discrimination), and
    * the generated data stays in-distribution, so the augmented model's
      weights average productively into the federated model (eq. 4) —
      a fully out-of-distribution generator makes weight blending
      destructive (observed and recorded in EXPERIMENTS.md)."""

    def __init__(self, dataset: str, fine_frac: float = 0.4,
                 noise: float = 0.30):
        self.dataset = dataset
        self.fine_frac = fine_frac
        self.noise = noise

    def generate(self, labels: np.ndarray, rng: np.random.Generator,
                 round_idx: int = 0):
        """Vectorized: one batched pattern lookup + gather-roll instead of a
        per-image Python loop (this sits on the per-round hot path of every
        AIGC strategy). Bitwise-identical to the loop form: the rng draw
        order (shifts, then noise) and float op order are preserved, and the
        roll is expressed as the equivalent modular gather."""
        labels = np.asarray(labels)
        n = len(labels)
        if n == 0:
            return np.empty((0, IMG, IMG, 3), np.float32)
        shifts = rng.integers(-4, 5, size=(n, 2))
        eps = rng.normal(0, self.noise,
                         size=(n, IMG, IMG, 3)).astype(np.float32)
        classes, inv = np.unique(labels, return_inverse=True)
        bank = np.stack([_oracle_pattern(self.dataset, int(c), self.fine_frac)
                         for c in classes])
        pats = bank[inv]                                   # [n, IMG, IMG, 3]
        # np.roll(p, (s0, s1), axis=(0, 1)) == p[(i - s0) % IMG, (j - s1) % IMG]
        rows = (np.arange(IMG)[None, :] - shifts[:, :1]) % IMG
        cols = (np.arange(IMG)[None, :] - shifts[:, 1:]) % IMG
        rolled = pats[np.arange(n)[:, None, None],
                      rows[:, :, None], cols[:, None, :]]
        return np.clip(0.8 * rolled + eps, -1, 1)


class DDPMGenerator:
    """Whole-schedule DDPM sampling with round-keyed streams.

    Historically this drew its PRNGKey from the runner's shared numpy
    Generator (`rng.integers(0, 2**31)`), which coupled generated images to
    every prior consumer of that stream — checkpoint resume and the
    vectorized/sequential paths replayed differently. It now derives the
    round-``t`` stream from ``SeedSequence((seed, t, GEN_KEY))``
    (gen/service.py, the fl/faults.py pattern) and never touches `rng`.
    `BatchedDDPMGenerator` additionally fuses multi-vehicle schedules into
    bucketed dispatches; this class keeps the one-dispatch-per-call shape
    for direct use."""

    def __init__(self, params, ddpm: DDPM, seed: int = 0,
                 sampler_steps: int | None = None):
        from repro.gen.service import BatchedDDPMGenerator
        self._inner = BatchedDDPMGenerator(
            params, ddpm, seed=seed,
            sampler_steps=ddpm.timesteps if sampler_steps is None
            else sampler_steps)
        self.params = params
        self.ddpm = ddpm

    def generate(self, labels: np.ndarray, rng: np.random.Generator,
                 round_idx: int = 0):
        return self._inner.generate(labels, rng, round_idx=round_idx)

"""RSU-side logic: augmented-model training on AIGC data and the EMD-weighted
aggregation (paper Sec. III-A step 5, eq. 4)."""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emd import aggregate, data_weights, kappas, mean_emd
from repro.fl.client import client_update


class GenFVServer:
    def __init__(self, cfg_model, global_params, generator, rng):
        self.cfg_model = cfg_model
        self.params = global_params
        self.generator = generator
        self.rng = rng
        self.pool_imgs: np.ndarray | None = None   # accumulated AIGC data
        self.pool_labels: np.ndarray | None = None
        # round-keyed generators (gen/service.py) take a round_idx kwarg;
        # bare `generate(labels, rng)` generators (third-party factories)
        # must keep working, so detect once here instead of try/except on
        # the hot path
        import inspect
        try:
            sig = inspect.signature(generator.generate)
            self._gen_round_kw = "round_idx" in sig.parameters
        except (TypeError, ValueError):
            self._gen_round_kw = False

    # ---- model augmentation (step 5) -------------------------------------
    def generate(self, label_counts: np.ndarray, round_idx: int = 0):
        labels = np.repeat(np.arange(len(label_counts)), label_counts)
        if len(labels) == 0:
            return 0
        if self._gen_round_kw:
            imgs = self.generator.generate(labels, self.rng,
                                           round_idx=round_idx)
        else:
            imgs = self.generator.generate(labels, self.rng)
        if self.pool_imgs is None:
            self.pool_imgs, self.pool_labels = imgs, labels.astype(np.int32)
        else:
            self.pool_imgs = np.concatenate([self.pool_imgs, imgs])
            self.pool_labels = np.concatenate(
                [self.pool_labels, labels.astype(np.int32)])
        return len(labels)

    def train_augmented(self, h: int, batch_size: int, lr: float):
        """omega_a update: h local steps on the generated pool (Sec. III-C1)."""
        if self.pool_imgs is None or len(self.pool_labels) < 2:
            return self.params, 0.0
        return client_update(self.params, self.cfg_model, self.pool_imgs,
                             self.pool_labels, self.rng, h, batch_size, lr)

    # ---- fused vehicle SGD + aggregation (fleet engine path) --------------
    def fleet_round(self, engine, imgs_list: List, labels_list: List,
                    sizes: Sequence[int], emds: Sequence[float],
                    aug_model=None, prox_mu: float = 0.0, *,
                    guard: bool = False, rhos=None, kappa_emds=None):
        """Run all selected vehicles' local SGD and the eq. (4) aggregation
        as one fused dispatch (fl/fleet.py). `self.params` is donated to the
        dispatch and rebound to the aggregated output. The sequential
        reference path is `client_update` per vehicle + `aggregate`.

        Fault-tolerance hooks (fl/faults.py callers only; defaults keep the
        fault-free dispatch byte-identical): `guard=True` switches to the
        finiteness-guarded kernel and returns a 4th element (finite mask);
        `rhos` overrides the data weights (the round loop pre-computes them
        jointly over fresh + buffered-stale participants); `kappa_emds`
        decouples the kappa2 EMD pool from `emds` for the same reason."""
        rhos = data_weights(sizes) if rhos is None \
            else np.asarray(rhos, np.float64)
        emd_bar = mean_emd(emds if kappa_emds is None else kappa_emds) \
            if aug_model is not None else 0.0
        if guard:
            self.params, losses, finite = engine.run(
                self.params, imgs_list, labels_list, rhos, emd_bar,
                aug_model, prox_mu, guard=True)
            return self.params, kappas(emd_bar), losses, finite
        self.params, losses = engine.run(self.params, imgs_list, labels_list,
                                         rhos, emd_bar, aug_model, prox_mu)
        return self.params, kappas(emd_bar), losses

    # ---- async merge-on-arrival (repro.fl.stream) -------------------------
    def absorb(self, model, weight: float):
        """Fold one late-arriving update into the global between rounds:
        params <- (1-w)*params + w*model. The streaming engine calls this
        for uploads that land in the gap after their round committed, with
        `weight` already carrying the rho·gamma^age staleness discount —
        the same first-order mass a next-round `add_weighted` merge would
        have granted the update, applied at its arrival instant instead.
        Float32 accumulation, matching `add_weighted`."""
        w = float(weight)
        self.params = jax.tree.map(
            lambda p, m: ((1.0 - w) * p.astype(jnp.float32)
                          + w * m.astype(jnp.float32)).astype(p.dtype),
            self.params, model)
        return self.params

    # ---- aggregation (eq. 4) ----------------------------------------------
    def aggregate(self, vehicle_models: List, sizes: Sequence[int],
                  emds: Sequence[float], aug_model=None, *,
                  rhos=None, kappa_emds=None):
        if not vehicle_models:
            if aug_model is not None:
                self.params = aug_model
            return self.params, (1.0, 0.0)
        rhos = data_weights(sizes) if rhos is None \
            else np.asarray(rhos, np.float64)
        emd_bar = mean_emd(emds if kappa_emds is None else kappa_emds)
        if aug_model is None:
            # FL-only: plain weighted FedAvg (kappa2 = 0)
            aug_model = vehicle_models[0]
            emd_bar = 0.0
        self.params = aggregate(vehicle_models, rhos, aug_model, emd_bar)
        return self.params, kappas(emd_bar)

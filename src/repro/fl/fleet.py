"""Vectorized fleet execution engine.

All K selected vehicles run their h local-SGD steps (including the FedProx
proximal branch) in ONE jitted dispatch: `jax.vmap` over a leading client
axis, every vehicle starting from the shared global model, fused with the
eq. (4) EMD-weighted aggregation as an on-device stacked-pytree weighted
reduction over the client axis (core/emd.py::aggregate_stacked, unrolled in
fixed order for cross-bucket bitwise stability). This replaces the
sequential per-vehicle
`client_update` loop + host-side `aggregate` of the reference path with a
single XLA program per round.

Fleet-size bucketing: K varies per round with SUBP1 selection, so batch
arrays are padded up to the next power-of-two bucket >= 4 (validity encoded
as zero aggregation weight) and jit compiles once per bucket instead of
once per distinct K. Padded slots train on all-zero batches — finite compute,
zero weight — and provably do not perturb the aggregate (tests/test_fleet.py
checks bitwise stability across buckets).

On accelerators the incoming global params are donated to the dispatch
(donate_argnums), so the aggregated model reuses their buffers; on CPU the
non-donating variant is used because XLA:CPU's aliasing perturbs fusion
bucket-dependently (breaking bitwise cross-bucket stability). Callers must
treat the passed pytree as consumed either way (GenFVServer rebinds
`self.params` to the output).

Design notes: DESIGN.md §"Vectorized fleet engine".
"""
from __future__ import annotations

from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.emd import aggregate_stacked, aggregate_stacked_guarded, kappas
# one bucket scheme for every padded dispatch in the repo: the fleet engine
# and the batched planner share it (defined in core/planner.py; re-exported
# here for existing callers)
from repro.core.planner import bucket_size  # noqa: F401
from repro.fl.client import local_sgd_steps


def _fleet_step_impl(cfg, h: int, lr: float, prox_mu: float, global_params,
                     imgs, labels, weights, aug_params, aug_weight):
    """The fused dispatch. imgs [K,h,B,H,W,C], labels [K,h,B], weights [K]
    (kappa1 * rho_n, zero on padding), aug_weight scalar (kappa2).

    Returns (aggregated global params, per-vehicle per-step losses [K,h]).
    """
    def one_vehicle(bi, bl):
        return local_sgd_steps(global_params, cfg, bi, bl, h, lr, prox_mu)

    stacked, losses = jax.vmap(one_vehicle)(imgs, labels)
    new_global = aggregate_stacked(stacked, weights, aug_params, aug_weight)
    return new_global, losses


# Two compiled variants. Donating the incoming global params lets XLA reuse
# their buffers for the aggregated output (no extra copy of the model on the
# accelerator), but the aliasing constraint perturbs XLA:CPU's fusion in a
# bucket-size-dependent way (~1 ULP drift between K=4 and K=8 buckets), which
# breaks the cross-bucket bitwise-stability guarantee — so on CPU the engine
# defaults to the non-donating variant (DESIGN.md §"Buffer donation").
_fleet_step_donated = partial(jax.jit, static_argnums=(0, 1, 2, 3),
                              donate_argnums=(4,))(_fleet_step_impl)
_fleet_step = partial(jax.jit, static_argnums=(0, 1, 2, 3))(_fleet_step_impl)


def _fleet_step_guarded_impl(cfg, h: int, lr: float, prox_mu: float,
                             global_params, imgs, labels, weights,
                             aug_params, aug_weight):
    """Fault-tolerant variant of the fused dispatch: identical vmapped local
    SGD, but the aggregation rejects non-finite (poisoned) client updates
    in-kernel and renormalizes survivor weights. Still one XLA program.

    Returns (aggregated global params, losses [K,h], finite_mask [K]).
    """
    def one_vehicle(bi, bl):
        return local_sgd_steps(global_params, cfg, bi, bl, h, lr, prox_mu)

    stacked, losses = jax.vmap(one_vehicle)(imgs, labels)
    new_global, finite = aggregate_stacked_guarded(
        stacked, weights, aug_params, aug_weight, fallback=global_params)
    return new_global, losses, finite


_fleet_step_guarded_donated = partial(jax.jit, static_argnums=(0, 1, 2, 3),
                                      donate_argnums=(4,))(_fleet_step_guarded_impl)
_fleet_step_guarded = partial(jax.jit, static_argnums=(0, 1, 2, 3))(
    _fleet_step_guarded_impl)


class FleetEngine:
    """Round executor: sample -> pad to bucket -> one fused dispatch.

    One engine per (model cfg, h, batch size, lr); bucketed jit caches live
    in jax's global compilation cache keyed on the static args + shapes.
    """

    def __init__(self, cfg_model, local_steps: int, batch_size: int,
                 lr: float, max_bucket: int = 64, donate: bool | None = None):
        # max_bucket caps trace size: the fixed-order reduction unrolls
        # O(bucket) adds per leaf, so huge buckets inflate compile time —
        # raise it explicitly for fleets beyond 64 concurrent vehicles
        self.cfg = cfg_model
        self.h = int(local_steps)
        self.batch_size = int(batch_size)
        self.lr = float(lr)
        self.max_bucket = max_bucket
        # donate=None: donate the global params on accelerators, keep the
        # bitwise bucket-stable non-donating dispatch on CPU (see above)
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self._step = _fleet_step_donated if self.donate else _fleet_step
        self._step_guarded = (_fleet_step_guarded_donated if self.donate
                              else _fleet_step_guarded)
        self._zeros = None  # cached kappa2=0 stand-in for a missing aug model

    # -- host-side batch sampling (mirrors client_update's rng protocol) ---
    def sample_batches(self, rng: np.random.Generator, images, labels):
        """One vehicle's h fixed-shape mini-batches (with replacement)."""
        idx = rng.integers(0, len(labels), size=(self.h, self.batch_size))
        return images[idx], labels[idx]

    # ----------------------------------------------------------------------
    def run(self, global_params, imgs_list: List, labels_list: List,
            rhos: Sequence[float], emd_bar: float = 0.0, aug_params=None,
            prox_mu: float = 0.0, bucket: int | None = None,
            guard: bool = False) -> Tuple[object, np.ndarray]:
        """Train all K vehicles and aggregate, in one dispatch.

        imgs_list/labels_list: per-vehicle stacked batches ([h,B,H,W,C] /
        [h,B]); rhos: data weights over the K vehicles; aug_params: the
        RSU-augmented model (None -> plain weighted FedAvg, kappa2 = 0).
        `global_params` must be treated as consumed (donated on
        accelerators). Returns (new globals, mean loss [K]); with
        guard=True (fault-injection runs) the guarded dispatch is used and a
        third element — per-vehicle finite mask [K] — is returned.
        """
        k = len(imgs_list)
        if k == 0:
            raise ValueError("FleetEngine.run needs at least one vehicle")
        kb = bucket_size(k, max_bucket=self.max_bucket) if bucket is None \
            else int(bucket)
        if kb < k:
            raise ValueError(f"bucket {kb} smaller than fleet {k}")

        imgs = np.stack([np.asarray(x, np.float32) for x in imgs_list])
        labels = np.stack([np.asarray(x, np.int32) for x in labels_list])
        if kb > k:
            pad = ((0, kb - k),) + ((0, 0),) * (imgs.ndim - 1)
            imgs = np.pad(imgs, pad)
            labels = np.pad(labels, ((0, kb - k),) + ((0, 0),) * (labels.ndim - 1))

        if aug_params is None:
            emd_bar = 0.0              # kappa2 = 0: pure weighted FedAvg
            if self._zeros is None:
                self._zeros = jax.tree.map(jnp.zeros_like, global_params)
            aug_params = self._zeros
        elif self.donate and aug_params is global_params:
            # empty-AIGC-pool rounds anchor kappa2 on the round-start globals
            # (server.train_augmented returns self.params untrained); copy so
            # donation of global_params can't clobber the aug input
            aug_params = jax.tree.map(jnp.copy, aug_params)
        k1, k2 = kappas(emd_bar)

        weights = np.zeros(kb, np.float32)
        weights[:k] = k1 * np.asarray(rhos, np.float64)

        args = (self.cfg, self.h, self.lr, float(prox_mu), global_params,
                jnp.asarray(imgs), jnp.asarray(labels), jnp.asarray(weights),
                aug_params, jnp.float32(k2))
        if guard:
            new_params, losses, finite = self._step_guarded(*args)
            return (new_params, np.asarray(losses[:k]).mean(axis=1),
                    np.asarray(finite[:k]))
        new_params, losses = self._step(*args)
        return new_params, np.asarray(losses[:k]).mean(axis=1)

"""GenFV round orchestration (paper Fig. 2 workflow + Algorithm 3), plus the
baseline schemes of Sec. VI-B: FedAvg, No-EMD, OCEAN-a, MADCA-FL, FL-only,
AIGC-only.

Each round:
  1. label sharing: vehicles report label histograms -> EMD_n
  2. SUBP1 selection (strategy-dependent)
  3. SUBP2-4 resource allocation (two-scale BCD) -> RoundPlan + delay ledger
  4. selected vehicles run h local SGD steps
  5. RSU generates b images (SUBP4 schedule) and trains the augmented model
  6. EMD-weighted aggregation (eq. 4)
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import read_manifest, restore_tree, save_tree
from repro.configs.base import GenFVConfig, StreamConfig
from repro.configs.genfv_cifar import CNNConfig, cnn_config
from repro.core import mobility, plan_round
from repro.core.emd import add_weighted, tree_finite
from repro.core.generation import label_schedule
from repro.core.planner import RoundPlan
from repro.core.selection import (dropout_mask, select, select_madca,
                                  select_no_emd, select_ocean, select_random)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DATASET_CLASSES, make_image_dataset
from repro.fl.client import client_update, local_sgd
from repro.fl.faults import (FaultInjector, FaultSpec, StaleBuffer,
                             StaleEntry, fault_names, get_fault,
                             realized_times)
from repro.fl.fleet import FleetEngine, bucket_size
from repro.fl.generator import OracleGenerator
from repro.fl.server import GenFVServer
from repro.models.cnn import cnn_forward, init_cnn
from repro.obs import NULL_OBS, Obs, log_line
from repro.sim import LEGACY, VehicularWorld, WorldState, get_scenario, \
    scenario_names

STRATEGIES = ("genfv", "fedavg", "no_emd", "madca", "ocean",
              "fl_only", "aigc_only", "fedprox")

#: SUBP2-4 backends understood by core/two_scale.py::plan_round.
PLANNERS = ("jax", "numpy")

#: AIGC services the round loop can serve SUBP4 schedules with: "oracle"
#: is the procedural quality-gap sampler (pinned fast reference, bitwise
#: frozen), "ddpm" the real batched diffusion dataplane (repro.gen) with
#: measured per-image cost fed into the eq. 12-13 delay terms.
GENERATORS = ("oracle", "ddpm")

# moderate client lr: high-lr few-class local models drift into incompatible
# basins and weight-average destructively
CLIENT_LR = 5e-2


def validate_run_fields(strategy: str, scenario: str, planner: str,
                        dataset: str, faults: str | None = None) -> None:
    """Registry validation shared by `RunConfig` and `repro.exp`'s
    `ExperimentSpec`: unknown names used to fail deep inside the round loop
    (or silently fall through string compares in `_alpha`); now they raise
    at construction with the valid names spelled out."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; valid: "
                         f"{', '.join(STRATEGIES)}")
    if scenario != LEGACY and scenario not in scenario_names():
        raise ValueError(
            f"unknown scenario {scenario!r}; registered: "
            f"{', '.join(scenario_names())} (or {LEGACY!r} for the "
            f"memoryless seed sampler)")
    if planner not in PLANNERS:
        raise ValueError(f"unknown planner {planner!r}; valid: "
                         f"{', '.join(PLANNERS)}")
    if dataset not in DATASET_CLASSES:
        raise ValueError(f"unknown dataset {dataset!r}; valid: "
                         f"{', '.join(DATASET_CLASSES)}")
    if faults is not None and faults not in fault_names():
        raise ValueError(f"unknown fault schedule {faults!r}; registered: "
                         f"{', '.join(fault_names())} (or None for a "
                         "fault-free run)")


def eval_stream_seed(seed: int) -> int:
    """RNG seed of the held-out eval set for run seed `seed`.

    The seed's `seed + 999` scheme collided under seed sweeps: cell 0's
    eval set drew from the same stream as cell 999's train set. Spawning a
    child `SeedSequence` instead gives every run seed an eval stream that
    no integer root seed (and no other run's spawn) can reproduce."""
    child = np.random.SeedSequence(seed).spawn(1)[0]
    return int(child.generate_state(1, np.uint64)[0])


@dataclass(frozen=True)
class RunConfig:
    """One experiment cell: frozen so `repro.exp` grids can expand, hash and
    serialize cells; validated at construction (`validate_run_fields`)."""
    dataset: str = "cifar10"
    alpha: float = 0.1
    rounds: int = 20
    strategy: str = "genfv"
    train_size: int = 4000
    test_size: int = 512
    width_mult: float = 0.25
    seed: int = 0
    model_bits: float | None = None      # default: 32 bits/param of the CNN
    vectorized: bool = True              # fused fleet engine vs sequential
                                         # per-vehicle reference path
    # Fleet source: a repro.sim scenario name (persistent world, default) or
    # "legacy" for the seed's memoryless per-round i.i.d. sampler.
    scenario: str = "highway_free_flow"
    # SUBP2-4 backend: "jax" (jitted/batched XLA kernel, default) or
    # "numpy" (host reference solver; pins the paper math bit-for-bit)
    planner: str = "jax"
    # Named fault schedule from fl/faults.py's registry, or None for the
    # fault-free loop (which then executes byte-identically to the seed:
    # tests/test_faults.py pins the no-injection equivalence).
    faults: str | None = None
    # Streaming round policy (configs/base.py::StreamConfig) consumed by
    # `repro.fl.stream.StreamEngine`; ignored by the synchronous `train()`
    # loop. None means "no streaming policy configured" (StreamEngine then
    # uses StreamConfig() defaults, which reproduce sync semantics). A plain
    # dict is coerced so checkpoint/spec payloads round-trip through JSON.
    stream: StreamConfig | None = None
    # AIGC service (GENERATORS): "oracle" or "ddpm" (repro.gen dataplane).
    generator: str = "oracle"
    # DDIM-style stride of the DDPM's full noise schedule — the SUBP4
    # quality/cost dial, swept as an ExperimentSpec axis. Ignored by the
    # oracle (which has no denoising loop).
    sampler_steps: int = 50
    # Observability handle (repro.obs): an `Obs` tracer/metrics registry,
    # or None for the zero-overhead null path. Excluded from equality,
    # hashing and serialization (`run_payload`) — two runs differing only
    # in obs are the same experiment, and attaching a tracer must never
    # change what the run computes (tests/test_obs.py pins bitwise parity).
    obs: Obs | None = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        validate_run_fields(self.strategy, self.scenario, self.planner,
                            self.dataset, self.faults)
        if self.generator not in GENERATORS:
            raise ValueError(f"unknown generator {self.generator!r}; "
                             f"valid: {', '.join(GENERATORS)}")
        if self.sampler_steps < 1:
            raise ValueError(
                f"sampler_steps must be >= 1, got {self.sampler_steps}")
        if isinstance(self.stream, dict):
            # frozen dataclass: rehydrate a JSON payload in place
            object.__setattr__(self, "stream",
                               StreamConfig.from_payload(self.stream))


def run_payload(run: "RunConfig") -> dict:
    """JSON-ready dict of the fields that identify the experiment — every
    RunConfig field except the `obs` handle (execution machinery, not
    configuration). Checkpoint fingerprints and sweep/spec artifacts all
    serialize through here so an attached tracer never leaks into (or
    invalidates) persisted state. The nested StreamConfig flattens to a
    plain dict (RunConfig.__post_init__ coerces it back)."""
    return {f.name: (getattr(run, f.name).to_payload()
                     if f.name == "stream" and run.stream is not None
                     else getattr(run, f.name))
            for f in dataclasses.fields(run) if f.name != "obs"}


@dataclass
class RoundLog:
    round: int
    selected: int
    t_bar: float
    b_gen: int
    kappa2: float
    emd_bar: float
    loss: float
    accuracy: float
    dropped: int = 0     # selected vehicles that left coverage mid-round
    # -- fault-tolerance ledger (fl/faults.py; all zero on fault-free runs) --
    late: int = 0          # missed the round deadline (straggler/outage)
    rejected: int = 0      # non-finite (poisoned) updates the guard refused
    stale_merged: int = 0  # buffered late updates merged this round
    stale_dropped: int = 0  # buffered updates aged past max_staleness
    t_round: float = 0.0   # realized wall-clock (= t_bar without faults)
    # -- planner diagnostics (core/planner.py; previously dropped) ---------
    bcd_iters: int = 0         # SUBP2-4 BCD outer iterations this round
    planner_converged: int = 1  # 0 iff the BCD hit its iteration cap


@dataclass
class RunResult:
    logs: List[RoundLog] = field(default_factory=list)

    def curve(self, key: str) -> np.ndarray:
        return np.array([getattr(l, key) for l in self.logs])


@dataclass
class PendingRound:
    """A round between `begin_round` (fleet + SUBP1 done) and
    `finish_round` (waiting on its SUBP2-4 `RoundPlan`)."""
    t: int
    fleet: List
    parts: np.ndarray
    alpha: np.ndarray


class GenFVRunner:
    #: manifest schema of `save_checkpoint` (bump on layout changes; v2
    #: added the RoundLog planner diagnostics bcd_iters/planner_converged,
    #: v3 the stale_dropped ledger column and the streaming-state block
    #: `repro.fl.stream.StreamEngine` appends, v4 the "gen" block recording
    #: the measured AIGC service so a resumed ddpm run replans against the
    #: RECORDED t0 instead of re-measuring — re-measurement would jitter
    #: eq. 48's b* and break bitwise resume)
    CKPT_SCHEMA = "repro.fl/runner-ckpt/v4"

    def __init__(self, run: RunConfig, fl_cfg: GenFVConfig | None = None,
                 generator=None, engine: FleetEngine | None = None,
                 dataset_fn: Callable | None = None,
                 faults: FaultSpec | None = None, obs=None, svc=None):
        self.run = run
        # explicit obs overrides the RunConfig handle (Sweep injects a
        # cell-tagged view of its shared tracer); default is the null path
        self.obs = obs if obs is not None else (
            run.obs if run.obs is not None else NULL_OBS)
        self.cfg = fl_cfg or GenFVConfig(dirichlet_alpha=run.alpha)
        self.scenario = None if run.scenario == LEGACY \
            else get_scenario(run.scenario)
        if self.scenario is not None:
            # overlay the scenario's physical-layer overrides (speed law,
            # geometry, arrival rate, shadowing) onto the FL config
            self.cfg = self.scenario.apply(self.cfg)
        self.rng = np.random.default_rng(run.seed)
        self.cnn_cfg: CNNConfig = cnn_config(run.dataset, run.width_mult)
        classes = DATASET_CLASSES[run.dataset]

        # dataset_fn lets repro.exp's Sweep share one dataset build across
        # grid cells (identical (name, n, seed) calls -> identical arrays,
        # so the cache is exact, not approximate)
        dataset_fn = dataset_fn or make_image_dataset
        imgs, labels = dataset_fn(run.dataset, run.train_size, seed=run.seed)
        self.test_imgs, self.test_labels = dataset_fn(
            run.dataset, run.test_size, seed=eval_stream_seed(run.seed))
        parts = dirichlet_partition(labels, self.cfg.num_vehicles, run.alpha,
                                    self.rng)
        self.client_data = [(imgs[ix], labels[ix]) for ix in parts]
        self.hists = [np.bincount(labels[ix], minlength=classes) /
                      max(len(ix), 1) for ix in parts]
        self.sizes = [len(ix) for ix in parts]
        # persistent world: one data partition per vehicle residency
        self.world = None if self.scenario is None else VehicularWorld(
            self.cfg, self.scenario, n_partitions=len(self.client_data),
            rng=self.rng)

        key = jax.random.PRNGKey(run.seed)
        params = init_cnn(key, self.cnn_cfg)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        # explicit None check: model_bits=0.0 is a legal override (free comms)
        self.model_bits = (run.model_bits if run.model_bits is not None
                           else n_params * 32.0)
        # AIGC service selection. `generator`/`svc` injections override the
        # RunConfig (Sweep factories, tests); otherwise run.generator picks
        # the dataplane. The oracle path keeps svc=None so plan_round
        # constructs the assumed DiffusionService exactly as the seed did
        # (bitwise-frozen reference); the ddpm path prices eq. 48 against
        # the measured per-image wall-clock of the real sampler. Lazy
        # imports: repro.gen reaches repro.exp.artifacts, which would cycle
        # at module import time.
        self.svc = svc
        gen = generator
        if gen is None:
            if run.generator == "ddpm":
                from repro.gen.calib import calibrated_service
                from repro.gen.service import make_ddpm_generator
                gen = make_ddpm_generator(run.dataset, classes, run.seed,
                                          run.sampler_steps, obs=self.obs)
                if self.svc is None:
                    self.svc = calibrated_service(gen.params, gen.ddpm,
                                                  run.sampler_steps)
            else:
                gen = OracleGenerator(run.dataset)
        self.server = GenFVServer(self.cnn_cfg, params, gen, self.rng)
        # max_bucket at the hard ceiling: fleet size is Poisson(num_vehicles),
        # so K can exceed the engine's conservative default cap; buckets
        # compile lazily, an unused headroom costs nothing. An injected
        # engine (Sweep shares one per model shape) must match this runner's
        # dispatch signature exactly.
        if engine is not None:
            if (engine.cfg != self.cnn_cfg or engine.h != self.cfg.local_steps
                    or engine.batch_size != self.cfg.batch_size
                    or engine.lr != CLIENT_LR):
                raise ValueError(
                    "injected FleetEngine does not match this run's model "
                    f"shape: engine=({engine.cfg.name}, h={engine.h}, "
                    f"B={engine.batch_size}, lr={engine.lr}) vs run="
                    f"({self.cnn_cfg.name}, h={self.cfg.local_steps}, "
                    f"B={self.cfg.batch_size}, lr={CLIENT_LR})")
            self.engine = engine
        else:
            self.engine = FleetEngine(self.cnn_cfg, self.cfg.local_steps,
                                      self.cfg.batch_size, lr=CLIENT_LR,
                                      max_bucket=4096)
        self.classes = classes
        self.b_prev = 0
        # -- fault tolerance (tentpole; all dormant when spec is None) -----
        # explicit FaultSpec overrides the RunConfig's registry name (ad-hoc
        # schedules in tests/benchmarks without registering them)
        spec = faults if faults is not None else (
            get_fault(run.faults) if run.faults is not None else None)
        self.faults = FaultInjector(spec) if spec is not None else None
        self.stale = StaleBuffer()
        # -- resumable execution: completed-round log + cursor -------------
        self.logs: List[RoundLog] = []
        self.next_round = 0
        cfg_cnn = self.cnn_cfg
        self._eval = jax.jit(
            lambda p, x, y: jnp.mean(
                (jnp.argmax(cnn_forward(p, cfg_cnn, x), -1) == y)
                .astype(jnp.float32)))

    # ------------------------------------------------------------------
    def _alpha(self, fleet, round_idx: int) -> np.ndarray:
        s = self.run.strategy
        batches = self.cfg.local_steps
        if s in ("genfv", "aigc_only", "fl_only"):
            return select(self.cfg, fleet, self.model_bits, batches).alpha
        if s == "fedprox":
            return select_random(self.rng, fleet, k=max(
                1, int(0.3 * len(fleet))))
        if s == "fedavg":
            return select_random(self.rng, fleet, k=max(
                1, int(0.3 * len(fleet))))
        if s == "no_emd":
            return select_no_emd(self.cfg, fleet, self.model_bits, batches)
        if s == "madca":
            return select_madca(self.cfg, fleet, self.model_bits, batches)
        if s == "ocean":
            return select_ocean(self.cfg, fleet, self.model_bits, batches,
                                round_idx, self.run.rounds)
        raise ValueError(s)

    # ------------------------------------------------------------------
    # Round lifecycle. `run_round` = begin -> plan -> finish; repro.exp's
    # Sweep drives the same three phases but routes many cells' `plan`
    # calls through ONE `plan_rounds_batched` dispatch between begin and
    # finish. The split is RNG-neutral: `begin_round` consumes self.rng in
    # exactly the order the old monolithic body did, and planning draws no
    # randomness at all.
    # ------------------------------------------------------------------
    def begin_round(self, t: int) -> PendingRound:
        """Phase 1: materialize the round's fleet and run SUBP1 selection."""
        cfg = self.cfg
        # fleet of the round: vehicles map onto data partitions
        with self.obs.span("round/fleet", round=t):
            if self.world is None:
                # legacy memoryless sampler: a fresh i.i.d. fleet every
                # round, mapped onto a fresh permutation of the partitions
                order = self.rng.permutation(len(self.client_data))
                hists = [self.hists[i] for i in order]
                sizes = [self.sizes[i] for i in order]
                fleet = mobility.sample_fleet(self.rng, cfg, hists, sizes)
                parts = order                   # parts[j]: fleet[j]'s data
            else:
                fleet, parts = self.world.fleet(self.hists, self.sizes)

        with self.obs.span("round/select", round=t, fleet=len(fleet)):
            alpha = self._alpha(fleet, t) if fleet else np.zeros(0, np.int32)
        return PendingRound(t, fleet, parts, alpha)

    def plan(self, pending: PendingRound) -> RoundPlan:
        """Phase 2: SUBP2-4 resource allocation for one pending round."""
        # span key mirrors the jax planner's jit cache key (the padded
        # bucket size) so the first dispatch per bucket tags as "compile"
        bucket = bucket_size(len(pending.fleet)) if pending.fleet else 0
        key = (self.run.planner, bucket) if self.run.planner == "jax" else None
        # no sync needed: plan_round unpacks to host scalars (self-fencing)
        with self.obs.span("round/plan", key=key, round=pending.t,
                           planner=self.run.planner, bucket=bucket):
            plan = plan_round(self.cfg, pending.fleet, self.model_bits,
                              self.cfg.local_steps, b_prev=self.b_prev,
                              svc=self.svc,
                              alpha_override=pending.alpha,
                              planner=self.run.planner)
        return plan

    def finish_round(self, pending: PendingRound, plan: RoundPlan) -> RoundLog:
        """Phase 3 (synchronous semantics): realize faults, enforce the
        deadline t_bar*(1+slack), then execute the round.

        With a `FaultSpec` attached the round buffers late-but-finite
        updates for a staleness-discounted merge in a later round and
        rejects poisoned ones via the in-kernel finiteness guard
        (fl/faults.py). Without one every branch reduces bitwise to the
        seed semantics (tests/test_faults.py pins the equivalence).

        The execution body lives in `_execute_round`, parameterized by the
        late/skip partition and the stale-merge set — `repro.fl.stream`'s
        event-driven engine computes those from its quorum/deadline event
        simulation instead and drives the same body (the async merge path),
        so both loops share one aggregation/ledger/eval implementation."""
        cfg = self.cfg
        t = pending.t
        fleet = pending.fleet

        # ---- fault realization + round deadline ---------------------------
        spec = self.faults.spec if self.faults is not None else None
        rf = None
        late_mask = None
        t_round = plan.t_bar
        if spec is not None and plan.selected:
            rf = self.faults.draw(t, len(plan.selected))
            t_real = realized_times(cfg, fleet, plan, self.model_bits, rf,
                                    spec.outage_fade_db)
            deadline = plan.t_bar * (1.0 + spec.deadline_slack)
            late_mask = (t_real > deadline) & ~rf.departed
            # the RSU holds the round open until the last on-time upload —
            # or until the deadline, once anyone misses it / departs
            if late_mask.any() or rf.departed.any():
                t_round = float(deadline)
            else:
                t_round = float(max(plan.t_bar, float(t_real.max())))

        # Mid-round dropout (persistent world only): SUBP1 admitted against
        # min(t_hold, t_max), but the realized straggler window plan.t_bar is
        # only known after SUBP2-4 — a selected vehicle whose holding time
        # falls short of it leaves coverage before uploading and contributes
        # nothing. The legacy sampler has no vehicle persistence, so the
        # seed's semantics (everyone selected finishes) are kept there.
        survive = None
        if self.world is not None and plan.selected:
            t_run = min(t_round, cfg.t_max)
            survive = dropout_mask(cfg, fleet, plan.selected, t_run)

        # buffered late updates from EARLIER rounds become mergeable now;
        # weights are staleness-discounted sizes rho_eff ∝ |D_n| * gamma^age
        stale_models, stale_weights, stale_emds = [], [], []
        stale_dropped = 0
        if spec is not None and self.run.strategy != "aigc_only":
            entries, ages, stale_dropped = self.stale.pop_mergeable(
                t, spec.max_staleness)
            stale_models = [e.params for e in entries]
            stale_weights = [e.size * spec.staleness_discount ** a
                             for e, a in zip(entries, ages)]
            stale_emds = [e.emd for e in entries]

        return self._execute_round(
            pending, plan, rf=rf, late_mask=late_mask, t_round=t_round,
            survive=survive, stale_models=stale_models,
            stale_weights=stale_weights, stale_emds=stale_emds,
            stale_dropped=stale_dropped, guard_host=spec is not None)

    def _execute_round(self, pending: PendingRound, plan: RoundPlan, *,
                       rf, late_mask, t_round: float, survive,
                       stale_models: List, stale_weights: List[float],
                       stale_emds: List[float], stale_dropped: int = 0,
                       late_sink: Callable | None = None,
                       skip_mask=None, guard_host: bool = False,
                       dt_floor: float = 0.0) -> RoundLog:
        """Execute one planned round: training, generation, aggregation,
        world step, eval. Both round loops drive this body:

        * synchronous (`finish_round`): late_mask from the fault deadline,
          stale merges drained from `self.stale`, late updates pushed back
          into it (the default `late_sink`);
        * streaming (`repro.fl.stream.StreamEngine`): late/skip partition
          from the quorum-commit event simulation, stale merges folded from
          the in-flight queue at their arrival instants, late updates
          sunk back into that queue with their realized due times, and
          `dt_floor` carrying the streaming cadence into the world step.

        `stale_weights` are the already-discounted size weights (the caller
        owns the gamma^age policy); `guard_host` enables the host-side
        finiteness checks of the sequential reference path; `skip_mask`
        marks selected positions whose upload can never arrive (exhausted
        retry budgets) — they count as dropped without consuming RNG."""
        run = self.run
        cfg = self.cfg
        t = pending.t
        fleet, parts = pending.fleet, pending.parts
        self.b_prev = plan.b_gen
        if late_sink is None:
            late_sink = lambda entry, pos: self.stale.push(entry)  # noqa: E731

        dropped = 0
        use_aigc = run.strategy in ("genfv", "aigc_only")
        use_fl = run.strategy != "aigc_only"
        prox_mu = 0.1 if run.strategy == "fedprox" else 0.0

        # AIGC generation + augmented training run first: omega_a depends only
        # on the round-start global model, and the fused fleet dispatch below
        # consumes it as the kappa2 term of eq. (4).
        aug = None
        loss = 0.0
        if use_aigc:
            with self.obs.span("round/generate", round=t,
                               b_gen=plan.b_gen) as sp:
                counts = label_schedule(
                    plan.b_gen if use_fl else cfg.gen_batch * 4,
                    self.classes)
                self.server.generate(counts, round_idx=t)
                aug, aug_loss = self.server.train_augmented(
                    cfg.local_steps * cfg.rsu_steps_factor, cfg.batch_size,
                    lr=CLIENT_LR)
                sp.sync = aug
            if not use_fl:
                loss = aug_loss

        n_trained = 0
        late = rejected = 0
        stale_merged = len(stale_models)
        forced_out: List[int] = []        # vids force-departed this round
        msizes, memds = [], []
        if use_fl:
            models = []                # sequential reference path
            fsizes = []                # sizes of the finite (kept) models
            bimgs, blabels = [], []    # vectorized engine path
            n_poison = 0               # poisoned batches inside the dispatch
            with self.obs.span("round/local_sgd", round=t,
                               selected=len(plan.selected),
                               vectorized=int(run.vectorized)):
                for pos, j in enumerate(plan.selected):
                    if survive is not None and not survive[pos]:
                        dropped += 1
                        continue
                    if rf is not None and rf.departed[pos]:
                        dropped += 1   # forced exit: the update never arrives
                        forced_out.append(fleet[j].vid)
                        continue
                    if skip_mask is not None and skip_mask[pos]:
                        # retry budget exhausted (streaming): the upload can
                        # never arrive — dropped without consuming RNG
                        dropped += 1
                        continue
                    v = fleet[j]
                    di, dl = self.client_data[parts[j]]
                    if len(dl) < 2:
                        continue
                    is_late = late_mask is not None and bool(late_mask[pos])
                    is_poisoned = rf is not None and bool(rf.poisoned[pos])
                    if run.vectorized:
                        bi, bl = self.engine.sample_batches(self.rng, di, dl)
                        if is_late:
                            # missed the deadline: train on the
                            # already-sampled batches outside the fused
                            # dispatch and buffer the update for a
                            # staleness-discounted merge next round
                            late += 1
                            if is_poisoned:
                                rejected += 1  # poisoned AND late: dropped
                            else:
                                m, _ = local_sgd(
                                    self.server.params, self.cnn_cfg,
                                    jnp.asarray(bi), jnp.asarray(bl),
                                    cfg.local_steps, CLIENT_LR, prox_mu)
                                late_sink(StaleEntry(
                                    m, v.data_size, v.emd, t, v.vid), pos)
                            continue
                        if is_poisoned:
                            # NaN batches corrupt the update inside the fused
                            # dispatch; the in-kernel finiteness guard
                            # rejects it there (one XLA program either way)
                            bi = np.full_like(bi, np.nan)
                            n_poison += 1
                        bimgs.append(bi)
                        blabels.append(bl)
                    else:
                        m, l = client_update(self.server.params, self.cnn_cfg,
                                             di, dl, self.rng, cfg.local_steps,
                                             cfg.batch_size, lr=CLIENT_LR,
                                             prox_mu=prox_mu)
                        if is_poisoned:
                            m = jax.tree.map(
                                lambda x: jnp.full_like(x, jnp.nan), m)
                        if is_late:
                            late += 1
                            if tree_finite(m):
                                late_sink(StaleEntry(
                                    m, v.data_size, v.emd, t, v.vid), pos)
                            else:
                                rejected += 1
                            continue
                        if guard_host and not tree_finite(m):
                            # host-side guard (reference path): the vehicle
                            # still counts as a participant (it trained and
                            # uploaded; mirrors the in-kernel guard's
                            # accounting) but its weight mass renormalizes
                            # onto the finite survivors
                            rejected += 1
                            msizes.append(v.data_size)
                            memds.append(v.emd)
                            continue
                        models.append(m)
                        fsizes.append(v.data_size)
                        loss += l
                    msizes.append(v.data_size)
                    memds.append(v.emd)
            n_trained = len(msizes)

            # span key mirrors the fused dispatch's jit cache key — the
            # padded fleet bucket and the finiteness-guard flag select the
            # compiled XLA program (fl/fleet.py)
            agg_bucket = bucket_size(len(bimgs)) if bimgs else 0
            agg_guard = bool(n_poison)
            agg_key = ((agg_bucket, agg_guard)
                       if run.vectorized and bimgs else None)
            if self.obs.enabled and run.vectorized and bimgs:
                self.obs.gauge("fleet/bucket", agg_bucket)
                self.obs.observe("fleet/pad_waste",
                                 agg_bucket - len(bimgs))
            with self.obs.span("round/aggregate", key=agg_key, round=t,
                               guard=int(agg_guard),
                               stale=stale_merged) as sp:
                if run.vectorized and bimgs:
                    if n_poison or stale_models:
                        # recovery dispatch: joint fresh+stale weights, and
                        # the guarded kernel IFF a poisoned batch is actually
                        # inside it. The guard is numerically neutral on
                        # finite inputs, but it is a different fused XLA
                        # program (ULP-level drift in the vmapped SGD), so
                        # clean rounds must keep dispatching the seed's
                        # kernel to stay bitwise.
                        all_sizes = np.asarray(
                            list(msizes) + list(stale_weights), np.float64)
                        rho_all = all_sizes / max(all_sizes.sum(), 1.0)
                        emds_all = memds + stale_emds
                        out = self.server.fleet_round(
                            self.engine, bimgs, blabels, msizes, memds,
                            aug if use_aigc else None, prox_mu,
                            guard=bool(n_poison),
                            rhos=(rho_all[:len(msizes)]
                                  if stale_models else None),
                            kappa_emds=emds_all if stale_models else None)
                        if n_poison:
                            _, (k1, k2), losses, finite = out
                            rejected += int((~finite).sum())
                            loss = float(losses[finite].mean()) \
                                if finite.any() else 0.0
                        else:
                            _, (k1, k2), losses = out
                            loss = float(losses.mean())
                        if stale_models:
                            w = (k1 * rho_all[len(msizes):]).tolist()
                            self.server.params = add_weighted(
                                self.server.params, stale_models, w)
                    else:
                        _, (k1, k2), losses = self.server.fleet_round(
                            self.engine, bimgs, blabels, msizes, memds,
                            aug if use_aigc else None, prox_mu)
                        loss = float(losses.mean())
                else:
                    if guard_host and not models and not stale_models \
                            and msizes:
                        # every upload rejected: the federated mass degrades
                        # to the round-start global (no federated progress),
                        # mirroring the guarded kernel's all-poisoned
                        # fallback
                        models, fsizes = [self.server.params], [sum(msizes)]
                    # sizes follow the KEPT models (guard-renormalized
                    # weights); the kappa2 EMD pool spans every participant,
                    # matching the vectorized kernel's accounting
                    _, (k1, k2) = self.server.aggregate(
                        models + stale_models,
                        list(fsizes) + list(stale_weights),
                        memds + stale_emds, aug if use_aigc else None)
                    loss = loss / max(len(models), 1)
                sp.sync = self.server.params

        if run.strategy == "aigc_only":
            self.server.params = aug
            k2 = 1.0
            emd_bar = 0.0
        else:
            emd_bar = float(np.mean(memds)) if memds else 0.0

        # advance the world by the realized round wall-clock: the straggler
        # window — deadline-extended under faults — (or the RSU's generation
        # window if longer — AIGC strategies only), floored so an empty round
        # still consumes its scheduling slot, capped at t_max
        if self.world is not None:
            with self.obs.span("round/world_step", round=t):
                if forced_out:
                    # fault-injected departures leave before the step (no
                    # RNG consumed, so a benign spec leaves the stream
                    # untouched)
                    self.world.remove(forced_out)
                t_rsu = plan.t_rsu if use_aigc else 0.0
                dt = max(t_round, t_rsu, dt_floor) if plan.selected \
                    else max(cfg.t_max, dt_floor)
                self.world.step(self.rng, float(
                    np.clip(dt, 0.25 * cfg.t_max, cfg.t_max)))

        # float() forces the device value: the eval span self-fences
        with self.obs.span("round/eval", round=t):
            acc = float(self._eval(self.server.params, self.test_imgs,
                                   self.test_labels))
        log = RoundLog(t, n_trained, plan.t_bar, plan.b_gen, k2,
                       emd_bar, float(loss), acc, dropped, late, rejected,
                       stale_merged, stale_dropped, float(t_round),
                       bcd_iters=plan.bcd_iters,
                       planner_converged=int(plan.converged))
        self._record_round(log)
        self.logs.append(log)
        self.next_round = t + 1
        return log

    def _record_round(self, log: RoundLog) -> None:
        """Feed the round's already-computed diagnostics — previously
        discarded on the floor — into the obs metrics registry. Pure
        host-side reads; the enabled guard keeps the null path free of even
        the kwargs allocations."""
        obs = self.obs
        if not obs.enabled:
            return
        run = self.run
        obs.observe("planner/bcd_iters", log.bcd_iters, planner=run.planner)
        obs.count("planner/converged", log.planner_converged,
                  planner=run.planner)
        obs.count("planner/rounds", 1, planner=run.planner)
        obs.observe("round/selected", log.selected)
        obs.observe("round/t_bar", log.t_bar)
        obs.observe("round/t_round", log.t_round)
        obs.observe("round/t_overrun", log.t_round - log.t_bar)
        obs.count("faults/late", log.late)
        obs.count("faults/rejected", log.rejected)
        obs.count("faults/stale_merged", log.stale_merged)
        obs.count("faults/stale_dropped", log.stale_dropped)
        obs.count("faults/dropped", log.dropped)
        if self.world is not None:
            self.world.observe(obs)

    def run_round(self, t: int) -> RoundLog:
        pending = self.begin_round(t)
        return self.finish_round(pending, self.plan(pending))

    # ------------------------------------------------------------------
    def train(self, verbose: bool = False, checkpoint_path: str | None = None,
              checkpoint_every: int = 1) -> RunResult:
        """Run (or resume) the remaining rounds. A freshly-constructed
        runner starts at round 0; after `load_checkpoint` the loop continues
        at the first incomplete round and the returned RunResult still spans
        all completed rounds. With `checkpoint_path`, state is saved
        atomically every `checkpoint_every` completed rounds."""
        for t in range(self.next_round, self.run.rounds):
            log = self.run_round(t)
            if verbose:
                # rate-limited structured logging (repro.obs): same human
                # rendering as the old bare print, but fast rounds coalesce
                # and the line doubles as a trace event when obs is enabled.
                # The final round always lands (force=).
                log_line(
                    self.obs, "train/round",
                    f"[{self.run.strategy}] round {t:3d} "
                    f"sel={log.selected:2d} drop={log.dropped} "
                    f"t_bar={log.t_bar:5.2f}s b={log.b_gen:4d} "
                    f"k2={log.kappa2:.3f} loss={log.loss:.3f} "
                    f"acc={log.accuracy:.3f}",
                    force=t == self.run.rounds - 1,
                    round=t, accuracy=log.accuracy)
            if checkpoint_path is not None and \
                    (t + 1) % max(checkpoint_every, 1) == 0:
                with self.obs.span("round/checkpoint", round=t):
                    self.save_checkpoint(checkpoint_path)
        return RunResult(list(self.logs))

    # ------------------------------------------------------------------
    # Resumable execution (ROADMAP direction 5). The runner's complete
    # mutable state is: global params, the single shared numpy Generator
    # (server and world hold it by identity), b_prev, the completed-round
    # logs, the AIGC pool, the world arrays and the staleness buffer.
    # Fault draws are round-keyed (fl/faults.py) and the datasets/partition
    # are a pure function of RunConfig, so nothing else needs persisting —
    # a resumed run replays the remaining rounds bitwise
    # (tests/test_faults.py golden resume, both planner backends).
    # ------------------------------------------------------------------
    _LOG_INT_FIELDS = ("round", "selected", "b_gen", "dropped", "late",
                       "rejected", "stale_merged", "stale_dropped",
                       "bcd_iters", "planner_converged")

    def _logs_state(self) -> dict:
        return {f.name: np.asarray([getattr(l, f.name) for l in self.logs],
                                   np.int64 if f.name in self._LOG_INT_FIELDS
                                   else np.float64)
                for f in dataclasses.fields(RoundLog)}

    def _checkpoint_state(self) -> dict:
        """The runner's complete mutable state as a checkpointable tree.
        `StreamEngine.save_checkpoint` reuses this and appends its own
        event-queue block under a key the sync layout never uses."""
        rng_state = np.frombuffer(
            json.dumps(self.rng.bit_generator.state).encode(), np.uint8)
        return {
            "rng": rng_state.copy(),
            "b_prev": np.int64(self.b_prev),
            "next_round": np.int64(self.next_round),
            "gen": ({} if self.svc is None else
                    {"t_image": np.float64(self.svc.t_per_image),
                     "steps": np.int64(getattr(self.svc, "steps", 0))}),
            "params": self.server.params,
            "logs": self._logs_state(),
            "pool": ({} if self.server.pool_imgs is None else
                     {"imgs": self.server.pool_imgs,
                      "labels": self.server.pool_labels}),
            "world": ({} if self.world is None else {
                "arrays": dataclasses.asdict(self.world.state),
                "free": np.asarray(self.world._free, np.int64),
                "next_vid": np.int64(self.world._next_vid),
                "stats": {k: np.float64(v) for k, v in
                          dataclasses.asdict(self.world.stats).items()},
            }),
            "stale": ({} if not self.stale.entries else {
                "params": [e.params for e in self.stale.entries],
                "size": np.asarray([e.size for e in self.stale.entries],
                                   np.int64),
                "emd": np.asarray([e.emd for e in self.stale.entries],
                                  np.float64),
                "trained_round": np.asarray(
                    [e.trained_round for e in self.stale.entries], np.int64),
                "vid": np.asarray([e.vid for e in self.stale.entries],
                                  np.int64),
            }),
        }

    def save_checkpoint(self, path: str) -> str:
        """Atomic snapshot of all mutable round state (repro.checkpoint)."""
        meta = {"schema": self.CKPT_SCHEMA,
                "run": run_payload(self.run)}
        return save_tree(path, self._checkpoint_state(), metadata=meta)

    def _check_manifest(self, meta: dict) -> None:
        if meta.get("schema") != self.CKPT_SCHEMA:
            raise ValueError(f"checkpoint schema {meta.get('schema')!r} != "
                             f"{self.CKPT_SCHEMA!r}")
        if meta.get("run") != run_payload(self.run):
            raise ValueError(
                "checkpoint was written by a different RunConfig: "
                f"{meta.get('run')} vs {run_payload(self.run)}")

    def load_checkpoint(self, path: str) -> int:
        """Restore a `save_checkpoint` snapshot into this (freshly
        constructed, identically configured) runner. Returns the next round
        to execute; `train()` continues from there."""
        meta = read_manifest(path)["metadata"]
        self._check_manifest(meta)
        if "stream_cfg" in meta:
            raise ValueError(
                "checkpoint was written by a streaming engine (it carries "
                "in-flight upload state); load it with "
                "repro.fl.stream.StreamEngine.load_checkpoint")
        self._restore_state(restore_tree(path))
        return self.next_round

    def _restore_state(self, state: dict) -> None:
        self.rng.bit_generator.state = json.loads(
            bytes(np.asarray(state["rng"], np.uint8)).decode())
        self.b_prev = int(state["b_prev"])
        self.next_round = int(state["next_round"])
        g = state.get("gen", {})
        if g:
            from repro.gen.calib import MeasuredService
            self.svc = MeasuredService(t_image=float(g["t_image"]),
                                       steps=int(g["steps"]))
        self.server.params = jax.tree.map(jnp.asarray, state["params"])
        logs = state["logs"]
        names = [f.name for f in dataclasses.fields(RoundLog)]
        self.logs = [
            RoundLog(**{n: (int(logs[n][i]) if n in self._LOG_INT_FIELDS
                            else float(logs[n][i])) for n in names})
            for i in range(len(logs["round"]))]
        pool = state["pool"]
        self.server.pool_imgs = (np.asarray(pool["imgs"], np.float32)
                                 if pool else None)
        self.server.pool_labels = (np.asarray(pool["labels"], np.int32)
                                   if pool else None)
        if self.world is not None:
            w = state["world"]
            if not w:
                raise ValueError("checkpoint has no world state but this "
                                 "run uses a persistent scenario")
            a = w["arrays"]
            self.world.state = WorldState(
                vid=np.asarray(a["vid"], np.int64),
                x=np.asarray(a["x"], np.float64),
                v=np.asarray(a["v"], np.float64),
                phi_max=np.asarray(a["phi_max"], np.float64),
                f_mem=np.asarray(a["f_mem"], np.float64),
                f_core=np.asarray(a["f_core"], np.float64),
                v_core=np.asarray(a["v_core"], np.float64),
                shadow_db=np.asarray(a["shadow_db"], np.float64),
                partition=np.asarray(a["partition"], np.int64))
            self.world._free = [int(p) for p in np.asarray(w["free"])]
            self.world._next_vid = int(w["next_vid"])
            st = w["stats"]
            self.world.stats.time = float(st["time"])
            self.world.stats.steps = int(st["steps"])
            self.world.stats.arrivals = int(st["arrivals"])
            self.world.stats.departures = int(st["departures"])
            self.world.stats.blocked_arrivals = int(st["blocked_arrivals"])
            self.world._hists_src = None    # invalidate the hist cache
        stale = state["stale"]
        self.stale = StaleBuffer()
        if stale:
            for i in range(len(stale["size"])):
                self.stale.push(StaleEntry(
                    params=jax.tree.map(jnp.asarray, stale["params"][i]),
                    size=int(stale["size"][i]),
                    emd=float(stale["emd"][i]),
                    trained_round=int(stale["trained_round"][i]),
                    vid=int(stale["vid"][i])))

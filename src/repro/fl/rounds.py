"""GenFV round orchestration (paper Fig. 2 workflow + Algorithm 3), plus the
baseline schemes of Sec. VI-B: FedAvg, No-EMD, OCEAN-a, MADCA-FL, FL-only,
AIGC-only.

Each round:
  1. label sharing: vehicles report label histograms -> EMD_n
  2. SUBP1 selection (strategy-dependent)
  3. SUBP2-4 resource allocation (two-scale BCD) -> RoundPlan + delay ledger
  4. selected vehicles run h local SGD steps
  5. RSU generates b images (SUBP4 schedule) and trains the augmented model
  6. EMD-weighted aggregation (eq. 4)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GenFVConfig
from repro.configs.genfv_cifar import CNNConfig, cnn_config
from repro.core import mobility, plan_round
from repro.core.generation import label_schedule
from repro.core.planner import RoundPlan
from repro.core.selection import (dropout_mask, select, select_madca,
                                  select_no_emd, select_ocean, select_random)
from repro.data.partition import dirichlet_partition
from repro.data.synthetic import DATASET_CLASSES, make_image_dataset
from repro.fl.client import client_update
from repro.fl.fleet import FleetEngine
from repro.fl.generator import OracleGenerator
from repro.fl.server import GenFVServer
from repro.models.cnn import cnn_forward, init_cnn
from repro.sim import LEGACY, VehicularWorld, get_scenario, scenario_names

STRATEGIES = ("genfv", "fedavg", "no_emd", "madca", "ocean",
              "fl_only", "aigc_only", "fedprox")

#: SUBP2-4 backends understood by core/two_scale.py::plan_round.
PLANNERS = ("jax", "numpy")

# moderate client lr: high-lr few-class local models drift into incompatible
# basins and weight-average destructively
CLIENT_LR = 5e-2


def validate_run_fields(strategy: str, scenario: str, planner: str,
                        dataset: str) -> None:
    """Registry validation shared by `RunConfig` and `repro.exp`'s
    `ExperimentSpec`: unknown names used to fail deep inside the round loop
    (or silently fall through string compares in `_alpha`); now they raise
    at construction with the valid names spelled out."""
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; valid: "
                         f"{', '.join(STRATEGIES)}")
    if scenario != LEGACY and scenario not in scenario_names():
        raise ValueError(
            f"unknown scenario {scenario!r}; registered: "
            f"{', '.join(scenario_names())} (or {LEGACY!r} for the "
            f"memoryless seed sampler)")
    if planner not in PLANNERS:
        raise ValueError(f"unknown planner {planner!r}; valid: "
                         f"{', '.join(PLANNERS)}")
    if dataset not in DATASET_CLASSES:
        raise ValueError(f"unknown dataset {dataset!r}; valid: "
                         f"{', '.join(DATASET_CLASSES)}")


def eval_stream_seed(seed: int) -> int:
    """RNG seed of the held-out eval set for run seed `seed`.

    The seed's `seed + 999` scheme collided under seed sweeps: cell 0's
    eval set drew from the same stream as cell 999's train set. Spawning a
    child `SeedSequence` instead gives every run seed an eval stream that
    no integer root seed (and no other run's spawn) can reproduce."""
    child = np.random.SeedSequence(seed).spawn(1)[0]
    return int(child.generate_state(1, np.uint64)[0])


@dataclass(frozen=True)
class RunConfig:
    """One experiment cell: frozen so `repro.exp` grids can expand, hash and
    serialize cells; validated at construction (`validate_run_fields`)."""
    dataset: str = "cifar10"
    alpha: float = 0.1
    rounds: int = 20
    strategy: str = "genfv"
    train_size: int = 4000
    test_size: int = 512
    width_mult: float = 0.25
    seed: int = 0
    model_bits: float | None = None      # default: 32 bits/param of the CNN
    vectorized: bool = True              # fused fleet engine vs sequential
                                         # per-vehicle reference path
    # Fleet source: a repro.sim scenario name (persistent world, default) or
    # "legacy" for the seed's memoryless per-round i.i.d. sampler.
    scenario: str = "highway_free_flow"
    # SUBP2-4 backend: "jax" (jitted/batched XLA kernel, default) or
    # "numpy" (host reference solver; pins the paper math bit-for-bit)
    planner: str = "jax"

    def __post_init__(self):
        validate_run_fields(self.strategy, self.scenario, self.planner,
                            self.dataset)


@dataclass
class RoundLog:
    round: int
    selected: int
    t_bar: float
    b_gen: int
    kappa2: float
    emd_bar: float
    loss: float
    accuracy: float
    dropped: int = 0     # selected vehicles that left coverage mid-round


@dataclass
class RunResult:
    logs: List[RoundLog] = field(default_factory=list)

    def curve(self, key: str) -> np.ndarray:
        return np.array([getattr(l, key) for l in self.logs])


@dataclass
class PendingRound:
    """A round between `begin_round` (fleet + SUBP1 done) and
    `finish_round` (waiting on its SUBP2-4 `RoundPlan`)."""
    t: int
    fleet: List
    parts: np.ndarray
    alpha: np.ndarray


class GenFVRunner:
    def __init__(self, run: RunConfig, fl_cfg: GenFVConfig | None = None,
                 generator=None, engine: FleetEngine | None = None,
                 dataset_fn: Callable | None = None):
        self.run = run
        self.cfg = fl_cfg or GenFVConfig(dirichlet_alpha=run.alpha)
        self.scenario = None if run.scenario == LEGACY \
            else get_scenario(run.scenario)
        if self.scenario is not None:
            # overlay the scenario's physical-layer overrides (speed law,
            # geometry, arrival rate, shadowing) onto the FL config
            self.cfg = self.scenario.apply(self.cfg)
        self.rng = np.random.default_rng(run.seed)
        self.cnn_cfg: CNNConfig = cnn_config(run.dataset, run.width_mult)
        classes = DATASET_CLASSES[run.dataset]

        # dataset_fn lets repro.exp's Sweep share one dataset build across
        # grid cells (identical (name, n, seed) calls -> identical arrays,
        # so the cache is exact, not approximate)
        dataset_fn = dataset_fn or make_image_dataset
        imgs, labels = dataset_fn(run.dataset, run.train_size, seed=run.seed)
        self.test_imgs, self.test_labels = dataset_fn(
            run.dataset, run.test_size, seed=eval_stream_seed(run.seed))
        parts = dirichlet_partition(labels, self.cfg.num_vehicles, run.alpha,
                                    self.rng)
        self.client_data = [(imgs[ix], labels[ix]) for ix in parts]
        self.hists = [np.bincount(labels[ix], minlength=classes) /
                      max(len(ix), 1) for ix in parts]
        self.sizes = [len(ix) for ix in parts]
        # persistent world: one data partition per vehicle residency
        self.world = None if self.scenario is None else VehicularWorld(
            self.cfg, self.scenario, n_partitions=len(self.client_data),
            rng=self.rng)

        key = jax.random.PRNGKey(run.seed)
        params = init_cnn(key, self.cnn_cfg)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        # explicit None check: model_bits=0.0 is a legal override (free comms)
        self.model_bits = (run.model_bits if run.model_bits is not None
                           else n_params * 32.0)
        gen = generator or OracleGenerator(run.dataset)
        self.server = GenFVServer(self.cnn_cfg, params, gen, self.rng)
        # max_bucket at the hard ceiling: fleet size is Poisson(num_vehicles),
        # so K can exceed the engine's conservative default cap; buckets
        # compile lazily, an unused headroom costs nothing. An injected
        # engine (Sweep shares one per model shape) must match this runner's
        # dispatch signature exactly.
        if engine is not None:
            if (engine.cfg != self.cnn_cfg or engine.h != self.cfg.local_steps
                    or engine.batch_size != self.cfg.batch_size
                    or engine.lr != CLIENT_LR):
                raise ValueError(
                    "injected FleetEngine does not match this run's model "
                    f"shape: engine=({engine.cfg.name}, h={engine.h}, "
                    f"B={engine.batch_size}, lr={engine.lr}) vs run="
                    f"({self.cnn_cfg.name}, h={self.cfg.local_steps}, "
                    f"B={self.cfg.batch_size}, lr={CLIENT_LR})")
            self.engine = engine
        else:
            self.engine = FleetEngine(self.cnn_cfg, self.cfg.local_steps,
                                      self.cfg.batch_size, lr=CLIENT_LR,
                                      max_bucket=4096)
        self.classes = classes
        self.b_prev = 0
        cfg_cnn = self.cnn_cfg
        self._eval = jax.jit(
            lambda p, x, y: jnp.mean(
                (jnp.argmax(cnn_forward(p, cfg_cnn, x), -1) == y)
                .astype(jnp.float32)))

    # ------------------------------------------------------------------
    def _alpha(self, fleet, round_idx: int) -> np.ndarray:
        s = self.run.strategy
        batches = self.cfg.local_steps
        if s in ("genfv", "aigc_only", "fl_only"):
            return select(self.cfg, fleet, self.model_bits, batches).alpha
        if s == "fedprox":
            return select_random(self.rng, fleet, k=max(
                1, int(0.3 * len(fleet))))
        if s == "fedavg":
            return select_random(self.rng, fleet, k=max(
                1, int(0.3 * len(fleet))))
        if s == "no_emd":
            return select_no_emd(self.cfg, fleet, self.model_bits, batches)
        if s == "madca":
            return select_madca(self.cfg, fleet, self.model_bits, batches)
        if s == "ocean":
            return select_ocean(self.cfg, fleet, self.model_bits, batches,
                                round_idx, self.run.rounds)
        raise ValueError(s)

    # ------------------------------------------------------------------
    # Round lifecycle. `run_round` = begin -> plan -> finish; repro.exp's
    # Sweep drives the same three phases but routes many cells' `plan`
    # calls through ONE `plan_rounds_batched` dispatch between begin and
    # finish. The split is RNG-neutral: `begin_round` consumes self.rng in
    # exactly the order the old monolithic body did, and planning draws no
    # randomness at all.
    # ------------------------------------------------------------------
    def begin_round(self, t: int) -> PendingRound:
        """Phase 1: materialize the round's fleet and run SUBP1 selection."""
        cfg = self.cfg
        # fleet of the round: vehicles map onto data partitions
        if self.world is None:
            # legacy memoryless sampler: a fresh i.i.d. fleet every round,
            # mapped onto a fresh permutation of the data partitions
            order = self.rng.permutation(len(self.client_data))
            hists = [self.hists[i] for i in order]
            sizes = [self.sizes[i] for i in order]
            fleet = mobility.sample_fleet(self.rng, cfg, hists, sizes)
            parts = order                       # parts[j]: fleet[j]'s data
        else:
            fleet, parts = self.world.fleet(self.hists, self.sizes)

        alpha = self._alpha(fleet, t) if fleet else np.zeros(0, np.int32)
        return PendingRound(t, fleet, parts, alpha)

    def plan(self, pending: PendingRound) -> RoundPlan:
        """Phase 2: SUBP2-4 resource allocation for one pending round."""
        return plan_round(self.cfg, pending.fleet, self.model_bits,
                          self.cfg.local_steps, b_prev=self.b_prev,
                          alpha_override=pending.alpha,
                          planner=self.run.planner)

    def finish_round(self, pending: PendingRound, plan: RoundPlan) -> RoundLog:
        """Phase 3: execute the planned round (training, generation,
        aggregation, world step, eval)."""
        run = self.run
        cfg = self.cfg
        t = pending.t
        fleet, parts = pending.fleet, pending.parts
        self.b_prev = plan.b_gen

        # Mid-round dropout (persistent world only): SUBP1 admitted against
        # min(t_hold, t_max), but the realized straggler window plan.t_bar is
        # only known after SUBP2-4 — a selected vehicle whose holding time
        # falls short of it leaves coverage before uploading and contributes
        # nothing. The legacy sampler has no vehicle persistence, so the
        # seed's semantics (everyone selected finishes) are kept there.
        survive = None
        dropped = 0
        if self.world is not None and plan.selected:
            t_run = min(plan.t_bar, cfg.t_max)
            survive = dropout_mask(cfg, fleet, plan.selected, t_run)

        use_aigc = run.strategy in ("genfv", "aigc_only")
        use_fl = run.strategy != "aigc_only"
        prox_mu = 0.1 if run.strategy == "fedprox" else 0.0

        # AIGC generation + augmented training run first: omega_a depends only
        # on the round-start global model, and the fused fleet dispatch below
        # consumes it as the kappa2 term of eq. (4).
        aug = None
        loss = 0.0
        if use_aigc:
            counts = label_schedule(plan.b_gen if use_fl else cfg.gen_batch * 4,
                                    self.classes)
            self.server.generate(counts)
            aug, aug_loss = self.server.train_augmented(
                cfg.local_steps * cfg.rsu_steps_factor, cfg.batch_size,
                lr=CLIENT_LR)
            if not use_fl:
                loss = aug_loss

        n_trained = 0
        msizes, memds = [], []
        if use_fl:
            models = []                # sequential reference path
            bimgs, blabels = [], []    # vectorized engine path
            for pos, j in enumerate(plan.selected):
                if survive is not None and not survive[pos]:
                    dropped += 1
                    continue
                v = fleet[j]
                di, dl = self.client_data[parts[j]]
                if len(dl) < 2:
                    continue
                if run.vectorized:
                    bi, bl = self.engine.sample_batches(self.rng, di, dl)
                    bimgs.append(bi)
                    blabels.append(bl)
                else:
                    m, l = client_update(self.server.params, self.cnn_cfg,
                                         di, dl, self.rng, cfg.local_steps,
                                         cfg.batch_size, lr=CLIENT_LR,
                                         prox_mu=prox_mu)
                    models.append(m)
                    loss += l
                msizes.append(v.data_size)
                memds.append(v.emd)
            n_trained = len(msizes)
            if run.vectorized and bimgs:
                _, (k1, k2), losses = self.server.fleet_round(
                    self.engine, bimgs, blabels, msizes, memds,
                    aug if use_aigc else None, prox_mu)
                loss = float(losses.mean())
            else:
                _, (k1, k2) = self.server.aggregate(
                    models, msizes, memds, aug if use_aigc else None)
                loss = loss / max(len(models), 1)

        if run.strategy == "aigc_only":
            self.server.params = aug
            k2 = 1.0
            emd_bar = 0.0
        else:
            emd_bar = float(np.mean(memds)) if memds else 0.0

        # advance the world by the realized round wall-clock: the straggler
        # window (or the RSU's generation window if longer — AIGC strategies
        # only), floored so an empty round still consumes its scheduling
        # slot, capped at t_max
        if self.world is not None:
            t_rsu = plan.t_rsu if use_aigc else 0.0
            dt = max(plan.t_bar, t_rsu) if plan.selected else cfg.t_max
            self.world.step(self.rng,
                            float(np.clip(dt, 0.25 * cfg.t_max, cfg.t_max)))

        acc = float(self._eval(self.server.params, self.test_imgs,
                               self.test_labels))
        return RoundLog(t, n_trained, plan.t_bar, plan.b_gen, k2,
                        emd_bar, float(loss), acc, dropped)

    def run_round(self, t: int) -> RoundLog:
        pending = self.begin_round(t)
        return self.finish_round(pending, self.plan(pending))

    # ------------------------------------------------------------------
    def train(self, verbose: bool = False) -> RunResult:
        res = RunResult()
        for t in range(self.run.rounds):
            log = self.run_round(t)
            res.logs.append(log)
            if verbose:
                print(f"[{self.run.strategy}] round {t:3d} sel={log.selected:2d} "
                      f"drop={log.dropped} t_bar={log.t_bar:5.2f}s b={log.b_gen:4d} "
                      f"k2={log.kappa2:.3f} loss={log.loss:.3f} acc={log.accuracy:.3f}")
        return res

from repro.fl.fleet import FleetEngine
from repro.fl.rounds import (PLANNERS, STRATEGIES, GenFVRunner, PendingRound,
                             RoundLog, RunConfig, RunResult,
                             eval_stream_seed, validate_run_fields)

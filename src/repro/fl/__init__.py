from repro.fl.rounds import GenFVRunner, RunConfig

from repro.fl.faults import (FaultInjector, FaultSpec, RoundFaults,
                             StaleBuffer, StaleEntry, fault_names, get_fault,
                             register_fault)
from repro.fl.fleet import FleetEngine
from repro.fl.rounds import (PLANNERS, STRATEGIES, GenFVRunner, PendingRound,
                             RoundLog, RunConfig, RunResult,
                             eval_stream_seed, validate_run_fields)
from repro.fl.stream import InFlight, StreamEngine, StreamLog

from repro.fl.fleet import FleetEngine
from repro.fl.rounds import GenFVRunner, RunConfig

"""Seeded fault injection for GenFV rounds (ROADMAP direction 5).

The paper's premise is FL that survives vehicular reality — churn, channel
fades, heterogeneous compute — yet the base round loop models exactly one
failure (coverage dropout) and discards every late or corrupted update. This
module injects the other failure modes deterministically so robustness is a
measurable, regression-testable property:

  * compute stragglers  — per-vehicle slowdown multipliers on the eq.-6
    training delay t_cp (thermal throttling, contended GPU);
  * upload outages      — a deep shadow fade (dB) applied on top of the
    vehicle's slow-fading gain, re-pricing eq.-10 upload time at the
    planned (l, phi) allocation;
  * forced departures   — extra mid-round exits beyond the world's natural
    coverage churn (lane change, tunnel, ignition-off);
  * poisoned updates    — NaN client deltas (malfunctioning or adversarial
    OBU), caught by the in-kernel finiteness guard
    (core/emd.py::aggregate_stacked_guarded).

Determinism contract: every round draws from a fresh
`SeedSequence(spec.seed, round)` stream in a FIXED order (slowdown, outage,
departure, poison — k draws each), so faults are a pure function of
(spec, round, fleet size). Identical across vectorized/sequential paths,
across planner backends, and across checkpoint resume — the injector holds
no mutable state.

Recovery machinery lives here too: `StaleBuffer` keeps late-but-finite
updates and releases them to the next FL round with staleness-discounted
weights  rho_eff = rho * gamma^age  (gamma = spec.staleness_discount,
age = merge_round - trained_round), dropping entries older than
spec.max_staleness. arXiv:2401.09656 motivates merging stale vehicular
updates instead of discarding them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import channel, mobility

__all__ = [
    "FaultSpec", "RoundFaults", "FaultInjector", "StaleEntry", "StaleBuffer",
    "register_fault", "get_fault", "fault_names", "realized_times",
]


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault schedule. Frozen so it can ride inside
    RunConfig-adjacent payloads and checkpoint metadata; all probabilities
    are per-selected-vehicle per-round."""
    seed: int = 0
    start_round: int = 0            # first faulty round (inclusive)
    end_round: int | None = None    # first clean round again (None = never)
    straggler_prob: float = 0.0
    straggler_slowdown: float = 3.0  # multiplier on t_cp when straggling
    outage_prob: float = 0.0
    outage_fade_db: float = 20.0     # extra shadow fade during an outage
    departure_prob: float = 0.0
    poison_prob: float = 0.0
    # -- recovery policy ---------------------------------------------------
    deadline_slack: float = 0.25     # deadline = t_bar * (1 + slack)
    staleness_discount: float = 0.5  # gamma in rho_eff = rho * gamma^age
    max_staleness: int = 2           # rounds a buffered update stays usable

    def __post_init__(self):
        for name in ("straggler_prob", "outage_prob", "departure_prob",
                     "poison_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} outside [0, 1]")
        if self.straggler_slowdown < 1.0:
            raise ValueError("straggler_slowdown must be >= 1 (it multiplies "
                             "the planned training delay)")
        if self.deadline_slack < 0.0:
            raise ValueError("deadline_slack must be >= 0")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in (0, 1]")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")

    def active(self, t: int) -> bool:
        return t >= self.start_round and (self.end_round is None
                                          or t < self.end_round)

    def to_payload(self) -> dict:
        import dataclasses
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict) -> "FaultSpec":
        return cls(**payload)


# ---------------------------------------------------------------------------
# Registry — named schedules referencable from RunConfig.faults (a plain
# string, so frozen experiment cells stay hashable/serializable).
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, FaultSpec] = {}


def register_fault(name: str, spec: FaultSpec) -> FaultSpec:
    if name in _REGISTRY:
        raise ValueError(f"fault schedule {name!r} already registered")
    _REGISTRY[name] = spec
    return spec


def get_fault(name: str) -> FaultSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown fault schedule {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name]


def fault_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# The benchmark's headline schedules (bench_faults.py): platoon mass-dropout
# stresses SUBP1's admission when a convoy exits together; rush-hour deep
# fade stresses the deadline/staleness recovery path when uploads suddenly
# cost 20 dB more at the planned (l, phi).
register_fault("platoon_mass_dropout",
               FaultSpec(seed=101, start_round=2, departure_prob=0.45,
                         straggler_prob=0.15, straggler_slowdown=2.0))
register_fault("rush_hour_deep_fade",
               FaultSpec(seed=202, start_round=2, outage_prob=0.5,
                         outage_fade_db=20.0, deadline_slack=0.25))
register_fault("compute_stragglers",
               FaultSpec(seed=303, straggler_prob=0.4,
                         straggler_slowdown=4.0, deadline_slack=0.15))
register_fault("poison_minority",
               FaultSpec(seed=404, poison_prob=0.25))
register_fault("mixed_stress",
               FaultSpec(seed=505, start_round=1, straggler_prob=0.2,
                         straggler_slowdown=3.0, outage_prob=0.2,
                         departure_prob=0.1, poison_prob=0.1))


# ---------------------------------------------------------------------------
# Per-round realizations.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RoundFaults:
    """One round's realized faults over the K selected vehicles."""
    slowdown: np.ndarray   # [K] float, >= 1 (1 = nominal)
    outage: np.ndarray     # [K] bool — deep fade on the upload
    departed: np.ndarray   # [K] bool — forced mid-round exit
    poisoned: np.ndarray   # [K] bool — NaN update

    @property
    def any(self) -> bool:
        return bool((self.slowdown > 1.0).any() or self.outage.any()
                    or self.departed.any() or self.poisoned.any())


def _benign(k: int) -> RoundFaults:
    return RoundFaults(np.ones(k), np.zeros(k, bool), np.zeros(k, bool),
                       np.zeros(k, bool))


class FaultInjector:
    """Stateless draw engine: `draw(t, k)` is a pure function of
    (spec.seed, t, k), so resume-from-checkpoint replays faults exactly
    without persisting any injector state."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    def draw(self, t: int, k: int) -> RoundFaults:
        if k == 0 or not self.spec.active(t):
            return _benign(k)
        s = self.spec
        # round-keyed stream; FIXED draw order — never reorder these, the
        # determinism guard in tests/test_faults.py pins realizations
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=(s.seed, t)))
        slow = np.where(rng.random(k) < s.straggler_prob,
                        s.straggler_slowdown, 1.0)
        outage = rng.random(k) < s.outage_prob
        departed = rng.random(k) < s.departure_prob
        poisoned = rng.random(k) < s.poison_prob
        # a departed vehicle's update never arrives; poisoning it is moot
        poisoned &= ~departed
        return RoundFaults(slow, outage, departed, poisoned)


def realized_times(cfg: GenFVConfig, fleet: Sequence, plan,
                   model_bits: float, rf: RoundFaults,
                   fade_db: float) -> np.ndarray:
    """Per-selected realized round time under faults: straggler-inflated
    training plus the (possibly deep-faded) eq.-10 upload priced at the
    PLANNED allocation (l, phi) — the RSU committed the schedule before the
    fault materialized, which is exactly why a deadline is needed.
    """
    t_cp = rf.slowdown * np.asarray(plan.t_cp, np.float64)
    t_mu = np.asarray(plan.t_mu, np.float64).copy()
    if rf.outage.any():
        idx = [plan.selected[i] for i in np.nonzero(rf.outage)[0]]
        xs = np.array([fleet[j].x for j in idx], np.float64)
        gains = np.array([fleet[j].gain_db for j in idx], np.float64)
        dists = mobility.rsu_distances(cfg, xs)
        t_mu[rf.outage] = channel.upload_times(
            cfg, model_bits, np.asarray(plan.l, np.float64)[rf.outage],
            np.asarray(plan.phi, np.float64)[rf.outage], dists,
            gain_db=gains - fade_db)
    return t_cp + t_mu


# ---------------------------------------------------------------------------
# Staleness buffer.
# ---------------------------------------------------------------------------
@dataclass
class StaleEntry:
    params: object          # the late client's trained model (pytree)
    size: int               # |D_n|
    emd: float              # EMD_n
    trained_round: int      # round whose global it descended from
    vid: int                # vehicle id (diagnostics)


@dataclass
class StaleBuffer:
    """Late-but-finite updates waiting to be merged. FIFO per round; ages
    are measured in completed rounds."""
    entries: List[StaleEntry] = field(default_factory=list)

    def push(self, entry: StaleEntry) -> None:
        self.entries.append(entry)

    def __len__(self) -> int:
        return len(self.entries)

    def pop_mergeable(self, t: int, max_staleness: int
                      ) -> Tuple[List[StaleEntry], List[int]]:
        """Drain the buffer for the merge at round `t`: returns
        (mergeable entries, ages). Entries older than max_staleness are
        dropped (too stale to help — arXiv:2401.09656's bounded-staleness
        regime)."""
        merge, ages = [], []
        for e in self.entries:
            age = t - e.trained_round
            if age <= max_staleness:
                merge.append(e)
                ages.append(age)
        self.entries = []
        return merge, ages

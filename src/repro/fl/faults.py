"""Seeded fault injection for GenFV rounds (ROADMAP direction 5).

The paper's premise is FL that survives vehicular reality — churn, channel
fades, heterogeneous compute — yet the base round loop models exactly one
failure (coverage dropout) and discards every late or corrupted update. This
module injects the other failure modes deterministically so robustness is a
measurable, regression-testable property:

  * compute stragglers  — per-vehicle slowdown multipliers on the eq.-6
    training delay t_cp (thermal throttling, contended GPU);
  * upload outages      — a deep shadow fade (dB) applied on top of the
    vehicle's slow-fading gain, re-pricing eq.-10 upload time at the
    planned (l, phi) allocation;
  * forced departures   — extra mid-round exits beyond the world's natural
    coverage churn (lane change, tunnel, ignition-off);
  * poisoned updates    — NaN client deltas (malfunctioning or adversarial
    OBU), caught by the in-kernel finiteness guard
    (core/emd.py::aggregate_stacked_guarded).

Determinism contract: every round draws from a fresh
`SeedSequence(spec.seed, round)` stream in a FIXED order (slowdown, outage,
departure, poison — k draws each), so faults are a pure function of
(spec, round, fleet size). Identical across vectorized/sequential paths,
across planner backends, and across checkpoint resume — the injector holds
no mutable state.

Recovery machinery lives here too: `StaleBuffer` keeps late-but-finite
updates and releases them to the next FL round with staleness-discounted
weights  rho_eff = rho * gamma^age  (gamma = spec.staleness_discount,
age = merge_round - trained_round), dropping entries older than
spec.max_staleness. arXiv:2401.09656 motivates merging stale vehicular
updates instead of discarding them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.base import GenFVConfig
from repro.core import channel, mobility

__all__ = [
    "FaultSpec", "RoundFaults", "FaultInjector", "StaleEntry", "StaleBuffer",
    "register_fault", "get_fault", "fault_names", "realized_arrivals",
    "realized_times",
]


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault schedule. Frozen so it can ride inside
    RunConfig-adjacent payloads and checkpoint metadata; all probabilities
    are per-selected-vehicle per-round."""
    seed: int = 0
    start_round: int = 0            # first faulty round (inclusive)
    end_round: int | None = None    # first clean round again (None = never)
    straggler_prob: float = 0.0
    straggler_slowdown: float = 3.0  # multiplier on t_cp when straggling
    outage_prob: float = 0.0
    outage_fade_db: float = 20.0     # extra shadow fade during an outage
    departure_prob: float = 0.0
    poison_prob: float = 0.0
    # -- recovery policy ---------------------------------------------------
    deadline_slack: float = 0.25     # deadline = t_bar * (1 + slack)
    staleness_discount: float = 0.5  # gamma in rho_eff = rho * gamma^age
    max_staleness: int = 2           # rounds a buffered update stays usable

    def __post_init__(self):
        for name in ("straggler_prob", "outage_prob", "departure_prob",
                     "poison_prob"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name}={p} outside [0, 1]")
        if self.straggler_slowdown < 1.0:
            raise ValueError("straggler_slowdown must be >= 1 (it multiplies "
                             "the planned training delay)")
        if self.deadline_slack < 0.0:
            raise ValueError("deadline_slack must be >= 0")
        if not 0.0 < self.staleness_discount <= 1.0:
            raise ValueError("staleness_discount must be in (0, 1]")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")

    def active(self, t: int) -> bool:
        return t >= self.start_round and (self.end_round is None
                                          or t < self.end_round)

    def to_payload(self) -> dict:
        import dataclasses
        return dataclasses.asdict(self)

    @classmethod
    def from_payload(cls, payload: dict) -> "FaultSpec":
        return cls(**payload)


# ---------------------------------------------------------------------------
# Registry — named schedules referencable from RunConfig.faults (a plain
# string, so frozen experiment cells stay hashable/serializable).
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, FaultSpec] = {}


def register_fault(name: str, spec: FaultSpec) -> FaultSpec:
    if name in _REGISTRY:
        raise ValueError(f"fault schedule {name!r} already registered")
    _REGISTRY[name] = spec
    return spec


def get_fault(name: str) -> FaultSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown fault schedule {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}")
    return _REGISTRY[name]


def fault_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# The benchmark's headline schedules (bench_faults.py): platoon mass-dropout
# stresses SUBP1's admission when a convoy exits together; rush-hour deep
# fade stresses the deadline/staleness recovery path when uploads suddenly
# cost 20 dB more at the planned (l, phi).
register_fault("platoon_mass_dropout",
               FaultSpec(seed=101, start_round=2, departure_prob=0.45,
                         straggler_prob=0.15, straggler_slowdown=2.0))
register_fault("rush_hour_deep_fade",
               FaultSpec(seed=202, start_round=2, outage_prob=0.5,
                         outage_fade_db=20.0, deadline_slack=0.25))
register_fault("compute_stragglers",
               FaultSpec(seed=303, straggler_prob=0.4,
                         straggler_slowdown=4.0, deadline_slack=0.15))
register_fault("poison_minority",
               FaultSpec(seed=404, poison_prob=0.25))
register_fault("mixed_stress",
               FaultSpec(seed=505, start_round=1, straggler_prob=0.2,
                         straggler_slowdown=3.0, outage_prob=0.2,
                         departure_prob=0.1, poison_prob=0.1))


# ---------------------------------------------------------------------------
# Per-round realizations.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class RoundFaults:
    """One round's realized faults over the K selected vehicles."""
    slowdown: np.ndarray   # [K] float, >= 1 (1 = nominal)
    outage: np.ndarray     # [K] bool — deep fade on the upload
    departed: np.ndarray   # [K] bool — forced mid-round exit
    poisoned: np.ndarray   # [K] bool — NaN update

    @property
    def any(self) -> bool:
        return bool((self.slowdown > 1.0).any() or self.outage.any()
                    or self.departed.any() or self.poisoned.any())


def _benign(k: int) -> RoundFaults:
    return RoundFaults(np.ones(k), np.zeros(k, bool), np.zeros(k, bool),
                       np.zeros(k, bool))


class FaultInjector:
    """Stateless draw engine: `draw(t, k)` is a pure function of
    (spec.seed, t, k), so resume-from-checkpoint replays faults exactly
    without persisting any injector state."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec

    def draw(self, t: int, k: int) -> RoundFaults:
        if k == 0 or not self.spec.active(t):
            return _benign(k)
        s = self.spec
        # round-keyed stream; FIXED draw order — never reorder these, the
        # determinism guard in tests/test_faults.py pins realizations
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=(s.seed, t)))
        slow = np.where(rng.random(k) < s.straggler_prob,
                        s.straggler_slowdown, 1.0)
        outage = rng.random(k) < s.outage_prob
        departed = rng.random(k) < s.departure_prob
        poisoned = rng.random(k) < s.poison_prob
        # a departed vehicle's update never arrives; poisoning it is moot
        poisoned &= ~departed
        return RoundFaults(slow, outage, departed, poisoned)


def _faded_upload_times(cfg: GenFVConfig, fleet: Sequence, plan,
                        model_bits: float, mask: np.ndarray,
                        fade_db: float) -> np.ndarray:
    """eq.-10 upload times for the `mask`ed selected positions, re-priced at
    the PLANNED (l, phi) under an extra `fade_db` shadow fade. Shared by the
    synchronous `realized_times` (outage = slow-but-successful upload) and
    the streaming `realized_arrivals` (outage = failed attempt + retry)."""
    idx = [plan.selected[i] for i in np.nonzero(mask)[0]]
    xs = np.array([fleet[j].x for j in idx], np.float64)
    gains = np.array([fleet[j].gain_db for j in idx], np.float64)
    dists = mobility.rsu_distances(cfg, xs)
    return channel.upload_times(
        cfg, model_bits, np.asarray(plan.l, np.float64)[mask],
        np.asarray(plan.phi, np.float64)[mask], dists,
        gain_db=gains - fade_db)


def realized_times(cfg: GenFVConfig, fleet: Sequence, plan,
                   model_bits: float, rf: RoundFaults,
                   fade_db: float) -> np.ndarray:
    """Per-selected realized round time under faults: straggler-inflated
    training plus the (possibly deep-faded) eq.-10 upload priced at the
    PLANNED allocation (l, phi) — the RSU committed the schedule before the
    fault materialized, which is exactly why a deadline is needed.
    """
    t_cp = rf.slowdown * np.asarray(plan.t_cp, np.float64)
    t_mu = np.asarray(plan.t_mu, np.float64).copy()
    if rf.outage.any():
        t_mu[rf.outage] = _faded_upload_times(cfg, fleet, plan, model_bits,
                                              rf.outage, fade_db)
    return t_cp + t_mu


#: entropy tag keying the per-attempt retry stream ("RTRY"), spawned per
#: round alongside — but distinct from — the draw() stream.
_RETRY_KEY = 0x52545259


def realized_arrivals(cfg: GenFVConfig, fleet: Sequence, plan,
                      model_bits: float, rf: RoundFaults, spec: FaultSpec,
                      t: int, *, retry_budget: int, backoff_s: float,
                      backoff_cap_s: float
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Streaming-mode realization (fl/stream.py): per-selected ABSOLUTE
    upload-completion offsets from the round start, with retry/backoff for
    outaged uploads.

    Unlike the synchronous `realized_times` — where an outage is a
    slow-but-successful upload the deadline judges — a streaming outage is a
    FAILED attempt: the transfer dies after the deep-faded airtime, the
    vehicle backs off min(backoff_s * 2^a, backoff_cap_s), and retries.
    Each retry draws channel recovery from a round-keyed per-attempt stream
    (`SeedSequence((spec.seed, t, _RETRY_KEY))`, one [K, budget] uniform
    block in fixed order — pure function of (spec, round, K), resumable):
    a recovered attempt is re-priced through the same eq.-10 pricing at the
    vehicle's refreshed (nominal) channel gain; a still-faded one burns the
    faded airtime again. A vehicle whose retry budget exhausts never
    arrives.

    Returns ``(times, retries, exhausted)`` over the K selected positions:
    arrival offsets (np.inf = the update never arrives), retry attempts
    consumed, and the permanently-failed mask. A departed vehicle's retry is
    NEVER scheduled — its update can never arrive (times=inf, retries=0).
    """
    k = len(plan.selected)
    t_cp = rf.slowdown * np.asarray(plan.t_cp, np.float64)
    t_mu = np.asarray(plan.t_mu, np.float64)
    times = t_cp + t_mu
    retries = np.zeros(k, np.int64)
    exhausted = np.zeros(k, bool)
    retrying = rf.outage & ~rf.departed   # departed: no retry, ever
    if retrying.any():
        t_fade = np.zeros(k, np.float64)
        t_fade[retrying] = _faded_upload_times(
            cfg, fleet, plan, model_bits, retrying, spec.outage_fade_db)
        rng = np.random.default_rng(
            np.random.SeedSequence(entropy=(spec.seed, t, _RETRY_KEY)))
        # one fixed-shape block, drawn whether or not every attempt is used
        u = rng.random((k, retry_budget)) if retry_budget else \
            np.zeros((k, 0))
        for pos in np.nonzero(retrying)[0]:
            acc = t_cp[pos] + t_fade[pos]        # attempt 0 dies in the fade
            recovered = False
            for a in range(retry_budget):
                acc += min(backoff_s * (2.0 ** a), backoff_cap_s)
                retries[pos] += 1
                if u[pos, a] >= spec.outage_prob:
                    acc += t_mu[pos]             # refreshed gain: nominal
                    recovered = True
                    break
                acc += t_fade[pos]               # still deep-faded: burn it
            if recovered:
                times[pos] = acc
            else:
                times[pos] = np.inf
                exhausted[pos] = True
    return np.where(rf.departed, np.inf, times), retries, exhausted


# ---------------------------------------------------------------------------
# Staleness buffer.
# ---------------------------------------------------------------------------
@dataclass
class StaleEntry:
    params: object          # the late client's trained model (pytree)
    size: int               # |D_n|
    emd: float              # EMD_n
    trained_round: int      # round whose global it descended from
    vid: int                # vehicle id (diagnostics)


@dataclass
class StaleBuffer:
    """Late-but-finite updates waiting to be merged. FIFO per round; ages
    are measured in completed rounds."""
    entries: List[StaleEntry] = field(default_factory=list)

    def push(self, entry: StaleEntry) -> None:
        self.entries.append(entry)

    def __len__(self) -> int:
        return len(self.entries)

    def pop_mergeable(self, t: int, max_staleness: int
                      ) -> Tuple[List[StaleEntry], List[int], int]:
        """Drain the buffer for the merge at round `t`: returns
        (mergeable entries, ages, dropped). Entries older than
        max_staleness are dropped — too stale to help (arXiv:2401.09656's
        bounded-staleness regime) — and COUNTED: the round loop feeds the
        drop count into RoundLog's fault ledger (`stale_dropped`) and the
        `faults/stale_dropped` obs counter instead of discarding silently.
        An entry exactly at ``age == max_staleness`` still merges (the
        bound is inclusive; tests/test_faults.py pins the boundary)."""
        merge, ages = [], []
        dropped = 0
        for e in self.entries:
            age = t - e.trained_round
            if age <= max_staleness:
                merge.append(e)
                ages.append(age)
            else:
                dropped += 1
        self.entries = []
        return merge, ages, dropped

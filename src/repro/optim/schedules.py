"""LR schedules: cosine (default) and WSD (Warmup-Stable-Decay, MiniCPM's
signature schedule, arXiv:2404.06395 §4)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)
    return f


def cosine_schedule(lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = (step - warmup) / jnp.maximum(total_steps - warmup, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd_schedule(lr: float, total_steps: int, warmup: int = 0,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> Stable (constant lr) -> Decay (last decay_frac of steps,
    exponential-style anneal to final_frac*lr)."""
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = (step - decay_start) / jnp.maximum(total_steps - decay_start, 1)
        t = jnp.clip(t, 0.0, 1.0)
        decay = lr * (final_frac ** t)
        out = jnp.where(step < decay_start, lr, decay)
        return jnp.where(step < warmup, warm, out)
    return f


def get_schedule(name: str, lr: float, total_steps: int, warmup: int = 0):
    if name == "wsd":
        return wsd_schedule(lr, total_steps, warmup)
    if name == "cosine":
        return cosine_schedule(lr, total_steps, warmup)
    return constant_schedule(lr)

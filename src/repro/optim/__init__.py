from repro.optim.optimizers import adamw, sgd, momentum, Optimizer, make_optimizer
from repro.optim.schedules import cosine_schedule, wsd_schedule, constant_schedule

"""Minimal optimizer library (optax-style pure functions, no dependency).

Each optimizer is an `Optimizer(init, update)` pair operating on pytrees.
`update` returns (new_params, new_state). LR is a schedule function of the
int step (kept inside the state).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _tree_zeros(params, dtype=None):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def sgd(schedule):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr = schedule(state["step"])
        new = jax.tree.map(lambda p, g: p - lr.astype(p.dtype) * g.astype(p.dtype),
                           params, grads)
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(schedule, beta: float = 0.9):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tree_zeros(params, jnp.float32)}

    def update(grads, state, params):
        lr = schedule(state["step"])
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        new = jax.tree.map(lambda p, m_: p - (lr * m_).astype(p.dtype), params, m)
        return new, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adamw(schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": _tree_zeros(params, jnp.float32),
                "v": _tree_zeros(params, jnp.float32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(state["step"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return p - (lr * u).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def make_optimizer(name: str, schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "momentum":
        return momentum(schedule, **kw)
    if name == "sgd":
        return sgd(schedule)
    raise ValueError(name)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn

"""ShapeDtypeStruct input specs for every (architecture x input shape)
combination — the dry-run lowers against these; nothing is allocated.

Shape semantics (assignment):
  train_4k      train_step   tokens/targets/mask [B, S]
  prefill_32k   prefill      tokens [B, S] + empty cache of capacity S
  decode_32k    serve_step   ONE token + cache of seq_len
  long_500k     serve_step   ONE token + cache of seq_len (sub-quadratic
                             archs only; gemma2 runs its documented
                             local-window serving variant)

[vlm]/[audio] carve-out: patch/frame embeddings appear as precomputed
inputs of the right shape (the frontend itself is stubbed).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import InputShape, ModelConfig
from repro.models import api
from repro.models.transformer import VISION_EMBED_DIM

SDS = jax.ShapeDtypeStruct


def params_specs(cfg: ModelConfig, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg,
                                                  dtype=dtype))


def opt_specs(cfg: ModelConfig, optimizer, dtype=jnp.bfloat16):
    p = params_specs(cfg, dtype)
    return jax.eval_shape(optimizer.init, p)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: api.init_cache(cfg, batch, max_len,
                                                 dtype=dtype))


def batch_specs(cfg: ModelConfig, shape: InputShape, *, train: bool,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    text = S
    out: Dict[str, Any] = {}
    if cfg.modality == "vision":
        text = S - cfg.frontend_tokens
        out["patch_embeds"] = SDS((B, cfg.frontend_tokens, VISION_EMBED_DIM), dtype)
    if cfg.modality == "audio" and train:
        out["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), dtype)
    out["tokens"] = SDS((B, text), jnp.int32)
    if train:
        out["targets"] = SDS((B, text), jnp.int32)
        out["mask"] = SDS((B, text), jnp.float32)
    return out


def input_specs(cfg: ModelConfig, shape: InputShape, optimizer=None,
                dtype=jnp.bfloat16) -> Tuple[Tuple, str]:
    """Returns (args_specs, step_kind) for the jitted step of this shape.

    train:   step(params, opt_state, batch)
    prefill: step(params, cache, batch)
    decode:  step(params, cache, tokens, positions)
    """
    if shape.kind == "train":
        assert optimizer is not None
        return ((params_specs(cfg, dtype), opt_specs(cfg, optimizer, dtype),
                 batch_specs(cfg, shape, train=True, dtype=dtype)), "train")
    if shape.kind == "prefill":
        return ((params_specs(cfg, dtype),
                 cache_specs(cfg, shape.global_batch, shape.seq_len, dtype),
                 batch_specs(cfg, shape, train=False, dtype=dtype)), "prefill")
    # decode: one new token against a cache of seq_len
    B = shape.global_batch
    return ((params_specs(cfg, dtype),
             cache_specs(cfg, B, shape.seq_len, dtype),
             SDS((B, 1), jnp.int32), SDS((B, 1), jnp.int32)), "decode")


def runnable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether this (arch, shape) pair is in scope (long_500k policy)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, cfg.long_context_note or "full attention; skipped per spec"
    return True, ""

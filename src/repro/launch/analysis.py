"""Analytic executed-FLOPs / executed-bytes model for the roofline table.

`compiled.cost_analysis()` counts every `lax.scan` body ONCE, so any FLOPs
inside the layer-group scan, the attention q/kv chunk scans, the chunked-CE
scan or the recurrent time scans are undercounted by their trip counts.
The dry-run therefore records BOTH the raw cost_analysis numbers and the
analytic model below, which mirrors exactly what our implementation
executes (e.g. dense-mode MoE counts all E experts; windowed layers still
compute all kv blocks because masking, not block skipping, enforces the
window — both honest inefficiencies the §Perf loop then attacks).

Conventions: 1 MAC = 2 FLOPs; train = fwd + remat-recompute + 2x bwd = 4x
forward FLOPs of the scanned stack (jax.checkpoint over layer groups);
embeddings/gathers are counted as bytes, not FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_MLSTM,
                                BLOCK_RGLRU, BLOCK_SLSTM, InputShape,
                                ModelConfig)


def _pad_to(x: int, c: int) -> int:
    return -(-x // c) * c


def model_flops(cfg, shape) -> float:
    """Closed-form MODEL_FLOPS: 6*N*D train (N = active params), 2*N*D for
    prefill, 2*N per decoded token (DESIGN.md §8)."""
    n_active = cfg.active_param_count()
    toks = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        return 2.0 * n_active * toks
    return 2.0 * n_active * shape.global_batch   # one token per sequence


def loop_trip_count(cfg) -> int:
    return max(cfg.num_layers // len(cfg.pattern), 1)


@dataclass
class FlopsBreakdown:
    attn_proj: float = 0.0
    attn_sdpa: float = 0.0
    mlp: float = 0.0
    moe: float = 0.0
    recurrent: float = 0.0
    head: float = 0.0
    encoder: float = 0.0
    frontend: float = 0.0

    @property
    def total(self) -> float:
        return (self.attn_proj + self.attn_sdpa + self.mlp + self.moe
                + self.recurrent + self.head + self.encoder + self.frontend)


def forward_flops(cfg: ModelConfig, B: int, Sq: int, Skv: int, *,
                  kv_chunk: int = 1024, q_chunk: int = 512,
                  moe_mode: str = "dense", long_window=None,
                  with_head: bool = True) -> FlopsBreakdown:
    """One forward pass: B sequences of Sq new tokens against Skv context."""
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    fb = FlopsBreakdown()
    toks = B * Sq

    # padded SDPA extents (our impl computes full padded blocks, mask only)
    sq_p = _pad_to(Sq, min(q_chunk, Sq))
    glu = cfg.mlp_type in ("swiglu", "geglu")
    mlp_f = (6 if glu else 4) * d * cfg.d_ff

    for kind in cfg.layer_kinds:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            cap = Skv
            if kind == ATTN_LOCAL and cfg.sliding_window:
                cap = min(Skv, cfg.sliding_window) if Sq == 1 else Skv
            if long_window is not None and kind == ATTN_GLOBAL and Sq == 1:
                cap = min(Skv, long_window)
            skv_p = _pad_to(cap, min(kv_chunk, cap))
            fb.attn_proj += toks * 2 * d * (nq * hd + 2 * nkv * hd + nq * hd)
            fb.attn_sdpa += B * sq_p * skv_p * nq * hd * 4
            if cfg.is_encdec:   # cross attention to encoder frames
                fb.attn_proj += toks * 2 * d * (nq * hd + nq * hd)
                fb.attn_sdpa += B * sq_p * _pad_to(cfg.encoder_seq, 1024) * nq * hd * 4
            if cfg.moe is not None:
                e = cfg.moe
                exp_f = (6 if glu else 4) * d * e.d_expert
                mult = e.num_experts if moe_mode == "dense" else \
                    e.experts_per_token * 1.25
                fb.moe += toks * (mult * exp_f + 2 * d * e.num_experts)
            elif cfg.d_ff > 0:
                fb.mlp += toks * mlp_f
        elif kind == BLOCK_RGLRU:
            w = cfg.lru_width or d
            fb.recurrent += toks * (2 * d * w * 3 + 4 * w * w
                                    + 2 * cfg.conv_kernel * w + 12 * w)
            fb.mlp += toks * mlp_f
        elif kind in (BLOCK_MLSTM, BLOCK_SLSTM):
            inner = int(d * cfg.proj_factor)
            if kind == BLOCK_MLSTM:
                h_ = cfg.num_heads
                hd_ = inner // h_
                cell = 6 * h_ * hd_ * hd_          # C update + n + Cq read
                fb.recurrent += toks * (4 * d * inner + 6 * inner * inner
                                        + 2 * cfg.conv_kernel * inner
                                        + cell + 2 * inner * d)
            else:
                h_ = cfg.num_heads
                hd_ = inner // h_
                fb.recurrent += toks * (2 * d * 4 * inner
                                        + 8 * h_ * hd_ * hd_ + 2 * inner * d)

    if cfg.is_encdec:
        # encoder self-attn + mlp over encoder frames
        ef = cfg.encoder_seq * B
        enc_p = _pad_to(cfg.encoder_seq, min(1024, cfg.encoder_seq))
        fb.encoder += cfg.encoder_layers * (
            ef * 2 * d * (nq * hd + 2 * nkv * hd + nq * hd)
            + B * enc_p * enc_p * nq * hd * 4
            + ef * mlp_f)
    if cfg.modality == "vision":
        from repro.models.transformer import VISION_EMBED_DIM
        fb.frontend += B * cfg.frontend_tokens * 2 * (VISION_EMBED_DIM * d + d * d)
    if with_head:
        fb.head += toks * 2 * d * cfg.vocab_size
    return fb


def executed_flops(cfg: ModelConfig, shape: InputShape, *,
                   moe_mode: str = "dense", long_window=None) -> dict:
    B, S = shape.global_batch, shape.seq_len
    # vlm: layers process frontend+text = S tokens; the LM head only sees text
    if shape.kind == "train":
        fwd = forward_flops(cfg, B, S, S, moe_mode=moe_mode, with_head=False)
        S_text = S - (cfg.frontend_tokens if cfg.modality == "vision" else 0)
        fwd.head = B * S_text * 2 * cfg.d_model * cfg.vocab_size
        total = 4.0 * fwd.total   # fwd + remat recompute + 2x bwd
    elif shape.kind == "prefill":
        fwd = forward_flops(cfg, B, S, S, moe_mode=moe_mode,
                            with_head=False)
        total = fwd.total + B * 2 * cfg.d_model * cfg.vocab_size  # last-tok head
    else:   # decode: ONE token against a cache of S
        fwd = forward_flops(cfg, B, 1, S, moe_mode=moe_mode,
                            long_window=long_window)
        total = fwd.total
    return {"total": total, "breakdown": fwd.__dict__}


def executed_bytes(cfg: ModelConfig, shape: InputShape, *,
                   param_bytes: int = 2, moe_mode: str = "dense",
                   long_window=None) -> dict:
    """Coarse HBM-traffic model (global bytes):

    * params: train -> fwd read + recompute read + bwd read + write + adam
      m/v fp32 read+write = 8*P*pb + 16*P ; inference -> one read.
    * activations: residual+block r/w ~ 8 reads/writes of [toks, d] per layer.
    * kv cache / recurrent state: read (+write) once per step.
    * logits: chunked CE reads hidden + writes per-chunk logits once.
    """
    P = cfg.param_count()
    d = cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    L = cfg.num_layers
    toks = B * (S if shape.kind != "decode" else 1)
    act = toks * d * param_bytes * 8 * L
    if shape.kind == "train":
        params = P * (4 * param_bytes + 16)
        logits = toks * cfg.vocab_size * 4 / 256 * 2   # one live chunk r/w
        cache = 0.0
    else:
        params = P * param_bytes
        logits = B * cfg.vocab_size * 4
        cache = 0.0
        for kind in cfg.layer_kinds:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                cap = S
                if kind == ATTN_LOCAL and cfg.sliding_window:
                    cap = min(S, cfg.sliding_window)
                if long_window is not None and kind == ATTN_GLOBAL:
                    cap = min(S, long_window)
                rw = 2 if shape.kind == "decode" else 1
                cache += B * cap * cfg.num_kv_heads * cfg.head_dim * 2 * param_bytes * rw
            elif kind == BLOCK_RGLRU:
                cache += B * (cfg.lru_width or d) * 4 * 2
            elif kind == BLOCK_MLSTM:
                inner = int(d * cfg.proj_factor)
                hd_ = inner // cfg.num_heads
                cache += B * cfg.num_heads * hd_ * hd_ * 4 * 2
            elif kind == BLOCK_SLSTM:
                cache += B * int(d * cfg.proj_factor) * 4 * 4 * 2
    total = params + act + cache + logits
    return {"total": total, "params": params, "activations": act,
            "cache": cache, "logits": logits}

"""End-to-end training driver for the assigned LM backbones.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --batch 8 --seq 128 [--reduced] [--impl pallas] \
      [--ckpt out.npz]

Runs on whatever devices are visible (1 CPU here; the production mesh is
exercised by launch/dryrun.py). Uses the arch's own schedule (WSD for
minicpm, cosine otherwise) and the reduced variant by default so the e2e
path is runnable on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_tree
from repro.configs import get_config
from repro.data.synthetic import batch_tokens, make_token_dataset
from repro.models import api
from repro.optim import make_optimizer
from repro.optim.schedules import get_schedule


def train(arch: str, steps: int = 50, batch: int = 8, seq: int = 128,
          reduced: bool = True, impl: str = "jnp", lr: float = 3e-4,
          ckpt: str | None = None, seed: int = 0, log_every: int = 10,
          optimizer: str = "adamw"):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    sched = get_schedule(cfg.schedule, lr, steps, warmup=max(steps // 20, 1))
    opt = make_optimizer(optimizer, sched)

    key = jax.random.PRNGKey(seed)
    params = api.init_params(key, cfg)
    state = opt.init(params)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {arch} ({'reduced' if reduced else 'FULL'}): "
          f"{n / 1e6:.2f}M params, schedule={cfg.schedule}")

    step_fn = jax.jit(api.make_train_step(cfg, opt, impl=impl))
    toks = make_token_dataset(cfg.vocab_size, batch * (seq + 1) * (steps + 2),
                              seed=seed)

    extras = {}
    if cfg.modality == "vision":
        extras["patch_embeds"] = jnp.asarray(
            np.random.default_rng(seed).normal(
                size=(batch, cfg.frontend_tokens, 1024)), jnp.float32)
    if cfg.modality == "audio":
        extras["frames"] = jnp.asarray(
            np.random.default_rng(seed).normal(
                size=(batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)

    losses = []
    t0 = time.time()
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in
             batch_tokens(toks, batch, seq, s).items()}
        b.update(extras)
        params, state, m = step_fn(params, state, b)
        losses.append(float(m["loss"]))
        if s % log_every == 0 or s == steps - 1:
            print(f"  step {s:4d} loss {losses[-1]:.4f} "
                  f"ce {float(m['ce']):.4f} gnorm {float(m['grad_norm']):.2f} "
                  f"({(time.time() - t0) / (s + 1):.2f}s/step)")
    if ckpt:
        save_tree(ckpt, params, metadata={"arch": arch, "steps": steps,
                                          "final_loss": losses[-1]})
        print(f"[train] checkpoint -> {ckpt}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (needs real accelerators)")
    ap.add_argument("--impl", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    _, losses = train(args.arch, args.steps, args.batch, args.seq,
                      reduced=not args.full, impl=args.impl, lr=args.lr,
                      ckpt=args.ckpt)
    ok = losses[-1] < losses[0]
    print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if ok else 'NOT improved'})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes and extract the
roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out benchmarks/artifacts]

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count on first init); smoke tests and benches import repro.* without
this module and keep seeing 1 device.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_archs      # noqa: E402
from repro.configs.base import V5E                                   # noqa: E402
from repro.distributed.autoshard import activation_sharding          # noqa: E402
from repro.distributed.sharding import (batch_shardings,             # noqa: E402
                                        cache_shardings,
                                        params_shardings, replicated)
from repro.launch import specs as S                                  # noqa: E402
from repro.launch.mesh import make_production_mesh                   # noqa: E402
from repro.models import api                                         # noqa: E402
from repro.optim import adamw, constant_schedule                     # noqa: E402

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1,
               "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8}


_MOE_MODE_FOR_DRYRUN = {"mode": "dense"}


def build_step(cfg, shape, optimizer, long_window=None):
    if shape.kind == "train":
        return api.make_train_step(cfg, optimizer, remat=True)
    if shape.kind == "prefill":
        return api.make_prefill_step(cfg, long_window=long_window)
    return api.make_decode_step(cfg, long_window=long_window)


def build_shardings(cfg, shape, mesh, args_specs, kind):
    p_sh = params_shardings(args_specs[0], mesh)
    if kind == "train":
        o_sh = params_shardings(args_specs[1], mesh)
        b_sh = batch_shardings(args_specs[2], mesh)
        return (p_sh, o_sh, b_sh), (p_sh, o_sh, replicated({"m": 0}, mesh)["m"])
    if kind == "prefill":
        c_sh = cache_shardings(args_specs[1], mesh)
        b_sh = batch_shardings(args_specs[2], mesh)
        out_logits = batch_shardings(jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vocab_size), jnp.float32), mesh)
        return (p_sh, c_sh, b_sh), (out_logits, c_sh)
    c_sh = cache_shardings(args_specs[1], mesh)
    t_sh = batch_shardings(args_specs[2], mesh)
    pos_sh = batch_shardings(args_specs[3], mesh)
    out_logits = batch_shardings(jax.ShapeDtypeStruct(
        (shape.global_batch, cfg.vocab_size), jnp.float32), mesh)
    return (p_sh, c_sh, t_sh, pos_sh), (out_logits, c_sh)


# ---------------------------------------------------------------------------
# Collective-byte accounting from optimized HLO
# ---------------------------------------------------------------------------
def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_scale: int = 1):
    """Sum output-shape bytes of every collective op; ops inside non-entry
    computations (scan bodies) are scaled by `loop_scale` (the layer-group
    trip count — DESIGN.md §8)."""
    per_kind = {}
    total = 0.0
    current_comp_is_entry = False
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY "):
            current_comp_is_entry = True
            continue
        if ls.endswith("{") and ("=" not in ls.split("{")[0]) and not ls.startswith("ENTRY"):
            if re.match(r"^%?[\w\.\-]+ ", ls) or ls.split("{")[0].strip().endswith(")"):
                current_comp_is_entry = False
        m = COLLECTIVE_RE.search(ls)
        if m and "=" in ls:
            kind = m.group(1)
            # result shape(s) sit between '=' and the op name:
            #   %x = bf16[16,512]{...} all-reduce(...)
            rhs = ls.split("=", 1)[1]
            head = rhs.split(m.group(1))[0]
            nbytes = _shape_bytes(head)
            scale = 1 if current_comp_is_entry else loop_scale
            per_kind[kind] = per_kind.get(kind, 0.0) + nbytes * scale
            total += nbytes * scale
    return total, per_kind


from repro.launch.analysis import loop_trip_count, model_flops  # noqa: E402


# ---------------------------------------------------------------------------
def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, dtype=jnp.bfloat16,
               step_override=None, tag: str = "baseline",
               moe_mode: str = "dense", cfg_overrides: dict | None = None):
    import dataclasses
    from repro.models.transformer import set_moe_mode
    set_moe_mode(moe_mode)
    _MOE_MODE_FOR_DRYRUN["mode"] = moe_mode
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    ok, note = S.runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "note": note,
                "mesh": {"pod": 2, "data": 16, "model": 16} if multi_pod
                else {"data": 16, "model": 16}}

    long_window = None
    if shape_name == "long_500k" and cfg.sliding_window and "local" not in cfg.pattern:
        pass
    if shape_name == "long_500k" and cfg.name.startswith("gemma2"):
        long_window = cfg.sliding_window

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    optimizer = adamw(constant_schedule(1e-4))
    args_specs, kind = S.input_specs(cfg, shape, optimizer, dtype=dtype)
    step = (step_override or build_step)(cfg, shape, optimizer, long_window)
    in_sh, out_sh = build_shardings(cfg, shape, mesh, args_specs, kind)

    donate = tuple(range(len(args_specs)))[:2] if kind == "train" else (1,)
    t0 = time.time()
    with mesh, activation_sharding(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception as e:            # backend may not implement it on CPU
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        for k in ("flops", "bytes accessed", "transcendentals"):
            if k in ca:
                cost[k] = float(ca[k])
    except Exception as e:
        cost["error"] = str(e)

    trip = loop_trip_count(cfg)
    hlo = compiled.as_text()
    cbytes, per_kind = collective_bytes(hlo, loop_scale=trip)

    # cost_analysis counts scan bodies once -> record raw numbers as a
    # cross-check; the roofline terms come from the analytic executed model
    # (launch/analysis.py, DESIGN.md §8).
    raw_flops = cost.get("flops", 0.0)
    hlo_flops_global = raw_flops * n_chips * trip
    hlo_bytes_global = cost.get("bytes accessed", 0.0) * n_chips * trip

    from repro.launch.analysis import executed_bytes, executed_flops
    moe_mode = _MOE_MODE_FOR_DRYRUN["mode"]
    ex_f = executed_flops(cfg, shape, moe_mode=moe_mode,
                          long_window=long_window)
    ex_b = executed_bytes(cfg, shape, moe_mode=moe_mode,
                          long_window=long_window)

    mf = model_flops(cfg, shape)
    compute_term = ex_f["total"] / (n_chips * V5E.peak_flops)
    memory_term = ex_b["total"] / (n_chips * V5E.hbm_bw)
    collective_term = cbytes / (n_chips * V5E.ici_bw)
    dominant = max((("compute", compute_term), ("memory", memory_term),
                    ("collective", collective_term)), key=lambda kv: kv[1])[0]

    rec = {
        "arch": arch, "shape": shape_name, "kind": kind, "tag": tag,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "dtype": str(dtype.__name__ if hasattr(dtype, "__name__") else dtype),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "cost_per_device_raw": cost,
        "loop_trip_count": trip,
        "hlo_flops_global_crosscheck": hlo_flops_global,
        "hlo_bytes_global_crosscheck": hlo_bytes_global,
        "executed_flops_global": ex_f["total"],
        "executed_flops_breakdown": ex_f["breakdown"],
        "executed_bytes_global": ex_b["total"],
        "executed_bytes_breakdown": {k: v for k, v in ex_b.items()
                                     if k != "total"},
        "collective_bytes_global": cbytes,
        "collective_by_kind": per_kind,
        "model_flops": mf,
        "useful_flops_ratio": mf / ex_f["total"] if ex_f["total"] else None,
        "moe_mode": moe_mode,
        "compute_term_s": compute_term,
        "memory_term_s": memory_term,
        "collective_term_s": collective_term,
        "dominant": dominant,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "skipped": False,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}] "
              f"kind={kind} compile={t_compile:.1f}s "
              f"compute={compute_term:.3f}s mem={memory_term:.3f}s "
              f"coll={collective_term:.3f}s dom={dominant} "
              f"useful={rec['useful_flops_ratio'] and round(rec['useful_flops_ratio'],3)}")
        if mem:
            print("  memory_analysis:", {k: f"{v/2**30:.2f}GiB" for k, v in mem.items()
                                         if isinstance(v, int)})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="benchmarks/artifacts")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper config: expert-parallel sorted MoE + "
                         "vocab padding where the TP axis does not divide")
    args = ap.parse_args()

    dtype = getattr(jnp, args.dtype)
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                kw = {}
                if args.optimized:
                    kw["moe_mode"] = "sorted_grouped"
                    kw["tag"] = "optimized"
                    if get_config(arch).vocab_size % 16:
                        kw["cfg_overrides"] = {"pad_vocab_multiple": 2048}
                try:
                    rec = dryrun_one(arch, shape, multi_pod=mp, dtype=dtype,
                                     **kw)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                           "error": f"{type(e).__name__}: {e}", "skipped": False}
                    print(f"[{arch} x {shape}] FAILED: {rec['error']}")
                results.append(rec)
                fn = f"{args.out}/dryrun_{arch.replace('.','_')}_{shape}_" \
                     f"{'mp' if mp else 'sp'}.json"
                with open(fn, "w") as f:
                    json.dump(rec, f, indent=1)
    n_err = sum(1 for r in results if r.get("error"))
    n_skip = sum(1 for r in results if r.get("skipped"))
    print(f"\ndone: {len(results)} combos, {n_err} errors, {n_skip} skipped")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Continuous-batching serving engine over the model zoo's serve_step.

Production pattern (vLLM-style, sized for the assigned decode shapes):

* fixed-size slot table — B concurrent sequences, each slot owning one lane
  of the batched KV cache / recurrent state (slot i == batch row i);
* admission: waiting requests claim free slots; their prompt is prefilled
  into the slot's cache lane via a single-lane prefill, then merged;
* one `decode_step` per engine tick advances EVERY active slot (the
  decode_32k / long_500k dry-run shape: one token against the shared
  cache);
* completion: slots free on EOS-length and are immediately reusable —
  requests of different lengths stream through without a global barrier.

The cache merge uses index-surgery on the cache pytree: every leaf's batch
dim is row-assigned. Works for all cache families (KV ring buffers,
RG-LRU / xLSTM recurrent states) because init_cache fixes the batch dim
position per leaf kind.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.obs import NULL_OBS


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [P] int32
    max_new_tokens: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False
    # per-request decode-tick deadline (None = engine default). A request
    # whose decode never terminates would otherwise own its slot forever
    # and starve every later admission; past the deadline it is evicted
    # (done=True, evicted=True) and the slot freed.
    deadline_ticks: Optional[int] = None
    evicted: bool = False


def _merge_lane(cache, lane_cache, row: int):
    """Copy lane 0 of `lane_cache` into batch row `row` of `cache`."""
    def merge(dst, src):
        # scalar leaf (no batch dim to row-assign): take the lane's value
        if dst.ndim == 0:
            return src
        # find the batch dim: first dim where dst is engine-batch-sized and
        # src is 1 (single-lane prefill). Caches built by init_cache keep
        # the batch dim at the same index for dst/src.
        for d in range(dst.ndim):
            if src.shape[d] == 1 and dst.shape[d] != 1:
                idx = [slice(None)] * dst.ndim
                idx[d] = row
                src_idx = [slice(None)] * src.ndim
                src_idx[d] = 0
                return dst.at[tuple(idx)].set(src[tuple(src_idx)])
        return src if dst.shape == src.shape else dst
    return jax.tree.map(merge, cache, lane_cache)


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 512,
                 impl: str = "jnp", dtype=jnp.float32, obs=None,
                 deadline_ticks: int | None = None):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        # default per-request eviction deadline; None = bounded only by
        # max_new_tokens/max_len (the pre-eviction behavior)
        self.deadline_ticks = deadline_ticks
        # repro.obs tracer: serve/prefill and serve/decode spans + queue
        # counters; NULL_OBS keeps the hot tick loop allocation-free
        self.obs = obs if obs is not None else NULL_OBS
        self.cache = api.init_cache(cfg, slots, max_len, dtype)
        self._prefill = jax.jit(api.make_prefill_step(cfg, impl=impl))
        self._decode = jax.jit(api.make_decode_step(cfg, impl=impl))
        self.active: Dict[int, Request] = {}      # slot -> request
        self.positions = np.zeros(slots, np.int64)
        self.last_tok = np.zeros(slots, np.int64)
        self.slot_ticks = np.zeros(slots, np.int64)  # decode ticks in slot
        self.waiting: List[Request] = []
        self._lane_cache_template = api.init_cache(cfg, 1, max_len, dtype)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        free = [s for s in range(self.slots) if s not in self.active]
        while free and self.waiting:
            slot = free.pop(0)
            req = self.waiting.pop(0)
            # key=prompt length: each distinct prefill shape compiles its
            # own program, and the span's first call per length tags it
            with self.obs.span("serve/prefill", key=len(req.prompt),
                               slot=slot, prompt_len=len(req.prompt)) as sp:
                lane = jax.tree.map(jnp.copy, self._lane_cache_template)
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, lane = self._prefill(self.params, lane,
                                             {"tokens": toks})
                self.cache = _merge_lane(self.cache, lane, slot)
                tok = int(jnp.argmax(logits[0]))
                sp.sync = self.cache
            req.out.append(tok)
            self.active[slot] = req
            self.positions[slot] = len(req.prompt)
            self.last_tok[slot] = tok
            self.slot_ticks[slot] = 0
            self.obs.count("serve/admitted")

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit, decode every active slot, retire."""
        self._admit()
        if not self.active:
            return []
        with self.obs.span("serve/decode", key=self.slots,
                           active=len(self.active)):
            toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
            pos = jnp.asarray(self.positions, jnp.int32)[:, None]
            logits, self.cache = self._decode(self.params, self.cache,
                                              toks, pos)
            # np.asarray forces the device value: the span self-fences
            nxt = np.asarray(jnp.argmax(logits, -1))
        self.obs.count("serve/decode_tokens", len(self.active))
        finished = []
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.out.append(tok)
            self.positions[slot] += 1
            self.last_tok[slot] = tok
            self.slot_ticks[slot] += 1
            if (len(req.out) >= req.max_new_tokens
                    or self.positions[slot] >= self.max_len - 1):
                req.done = True
                finished.append(req)
                del self.active[slot]
                continue
            # max-ticks eviction: a stuck decode frees its slot so later
            # admissions proceed instead of queueing forever
            deadline = req.deadline_ticks if req.deadline_ticks is not None \
                else self.deadline_ticks
            if deadline is not None and self.slot_ticks[slot] >= deadline:
                req.done = True
                req.evicted = True
                finished.append(req)
                del self.active[slot]
                self.obs.count("serve/evicted")
        return finished

    def run(self, max_ticks: int = 1000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_ticks):
            done += self.step()
            if not self.active and not self.waiting:
                break
        return done

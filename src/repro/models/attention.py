"""Attention: GQA/MQA/MHA with RoPE, sliding window, logit soft-cap, QKV bias,
ring-buffer KV caches, and cross-attention (enc-dec).

Two SDPA implementations:
  * "jnp"   — chunked online-softmax (flash-style) in pure jnp. Default; used
              by the dry-run (XLA-native) and CPU tests. The kv-chunk loop is
              a `lax.scan` so HLO stays small at 32k/512k context and the
              working set never materializes S_q x S_kv.
  * "pallas" — kernels/flash_attention.py (TPU target; interpret=True on CPU).

All masking is *position-based*: each cached slot stores its absolute token
position (-1 = empty), so causality, sliding windows and ring-buffer wraparound
fall out of one comparison.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_LOCAL
from repro.distributed.autoshard import aconstrain
from repro.models.layers import dense_init, rope

NEG_INF = -2.0 ** 30  # large finite; avoids NaN from (-inf) - (-inf)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype=jnp.float32):
    d, nq, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, nq * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    return p


# ---------------------------------------------------------------------------
# KV cache (ring buffer for local layers)
# ---------------------------------------------------------------------------
def init_kv_cache(cfg, kind: str, batch: int, max_len: int, dtype=jnp.float32):
    cap = max_len
    if kind == ATTN_LOCAL and cfg.sliding_window:
        cap = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
        # PER-ROW write cursor: rows advance independently (continuous
        # batching admits sequences at different positions), and the
        # mask-based writes below stay shardable over any cache axis.
        "idx": jnp.zeros((batch,), jnp.int32),
    }


def _cache_write_decode(cache, k_new, v_new, positions):
    """Write one token (k_new: [B,1,nkv,hd]) at per-row slot idx % cap.

    Mask-based scatter (arange == slot) instead of dynamic_update_slice:
    every row writes its own ring position, and GSPMD shards it without
    gathering the cache."""
    cap = cache["k"].shape[1]
    slot = (cache["idx"] % cap)[:, None]                     # [B,1]
    lane = jnp.arange(cap, dtype=jnp.int32)[None, :]         # [1,cap]
    hit = lane == slot                                       # [B,cap]
    k = jnp.where(hit[..., None, None], k_new.astype(cache["k"].dtype),
                  cache["k"])
    v = jnp.where(hit[..., None, None], v_new.astype(cache["v"].dtype),
                  cache["v"])
    pos = jnp.where(hit, positions.astype(jnp.int32), cache["pos"])
    return {"k": k, "v": v, "pos": pos, "idx": cache["idx"] + 1}


def _cache_write_prefill(cache, k_full, v_full, positions):
    """Fill the cache with the (last cap tokens of the) prefill sequence."""
    cap = cache["k"].shape[1]
    S = k_full.shape[1]
    if S >= cap:
        k, v, pos = k_full[:, -cap:], v_full[:, -cap:], positions[:, -cap:]
        idx = cache["idx"] + S
        return {"k": k.astype(cache["k"].dtype), "v": v.astype(cache["v"].dtype),
                "pos": pos.astype(jnp.int32), "idx": idx}
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_full.astype(cache["k"].dtype), 0, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_full.astype(cache["v"].dtype), 0, axis=1)
    pos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions.astype(jnp.int32), 0, axis=1)
    return {"k": k, "v": v, "pos": pos, "idx": cache["idx"] + S}


# ---------------------------------------------------------------------------
# Chunked online-softmax SDPA (pure jnp)
# ---------------------------------------------------------------------------
def sdpa_chunked(q, k, v, q_pos, kv_pos, *, causal: bool = True,
                 window: Optional[int] = None, attn_softcap=None,
                 kv_chunk: int = 1024, q_chunk: int = 512,
                 remat: bool = False):
    """q: [B,Sq,nq,hd]; k,v: [B,Skv,nkv,hd]; q_pos: [B,Sq]; kv_pos: [B,Skv].

    Flash-style double blocking: outer scan over q chunks, inner scan over
    kv chunks, online softmax in fp32. The live score block is
    [B, nkv, g, q_chunk, kv_chunk] — never Sq x Skv.

    Returns [B,Sq,nq,hd] (fp32 accumulated, cast back to q.dtype).
    """
    B, Sq, nq, hd = q.shape
    Skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5

    # pad kv to a chunk multiple; padded slots get pos = -1 (masked everywhere)
    kv_chunk = min(kv_chunk, Skv)
    pad = (-Skv) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    n_kv = (Skv + pad) // kv_chunk
    kc = k.reshape(B, n_kv, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_kv, kv_chunk, nkv, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(B, n_kv, kv_chunk).transpose(1, 0, 2)

    # pad q to a chunk multiple; padded q rows get pos large-negative so the
    # causal mask kills everything and the row normalizer is clamped.
    q_chunk = min(q_chunk, Sq)
    qpad = (-Sq) % q_chunk
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, qpad)), constant_values=-(2 ** 30))
    n_q = (Sq + qpad) // q_chunk
    qg = (q * scale).reshape(B, n_q, q_chunk, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(B, n_q, q_chunk).transpose(1, 0, 2)

    def kv_body(carry, xs):
        acc, m, l, q_i, qp_i = carry
        k_j, v_j, p_j = xs                                  # [B,C,nkv,hd], [B,C]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_i, k_j,
                       preferred_element_type=jnp.float32)  # [B,nkv,g,Qc,C]
        if attn_softcap is not None:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        valid = p_j[:, None, None, None, :] >= 0
        if causal:
            rel = qp_i[:, None, None, :, None] - p_j[:, None, None, None, :]
            valid &= rel >= 0
            if window is not None:
                valid &= rel < window
        s = jnp.where(valid, s, NEG_INF)
        m_i = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_i[..., None])
        alpha = jnp.exp(m - m_i)
        l_i = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_j.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc_i = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (acc_i, m_i, l_i, q_i, qp_i), None

    def q_body(_, xs):
        q_i, qp_i = xs                                      # [B,Qc,nkv,g,hd]
        acc0 = jnp.zeros((B, q_chunk, nkv, g, hd), jnp.float32)
        m0 = jnp.full((B, nkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nkv, g, q_chunk), jnp.float32)
        (acc, m, l, _, _), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0, q_i, qp_i), (kc, vc, pc))
        l = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, (acc / l).astype(q.dtype)

    # Checkpoint at the q-block level: the inner kv scan would otherwise save
    # its per-step carries (the fp32 accumulators) across BOTH scan levels
    # for backward — observed 36 GiB/device at 4k train. Recomputing the kv
    # sweep per q block bounds the resident set to one q-block's accumulators
    # (flash-attention backward, §Perf hillclimb 2 iter 2).
    q_body = jax.checkpoint(q_body)

    if n_q == 1:
        _, out = q_body(None, (qg[0], qp[0]))
        out = out[None]
    else:
        _, out = jax.lax.scan(q_body, None, (qg, qp))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq + qpad, nq, hd)
    return out[:, :Sq]


# ---------------------------------------------------------------------------
# Full attention layer
# ---------------------------------------------------------------------------
def attention(p, x, cfg, kind: str, positions, cache=None, cross_kv=None,
              impl: str = "jnp", kv_chunk: int = 1024, remat: bool = False,
              causal: bool = True):
    """x: [B,S,d]. Returns (y [B,S,d], new_cache).

    cross_kv: optional dict(k,v,pos) for encoder-decoder cross attention
    (no cache update, non-causal over encoder frames).
    """
    B, S, _ = x.shape
    nq, nkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"].astype(q.dtype)
    q = aconstrain(q.reshape(B, S, nq, hd), ("batch", None, "model", None))

    if cross_kv is not None:
        q = rope(q, positions, cfg.rope_theta) if cfg.norm == "rmsnorm" else q
        out = _sdpa_dispatch(q, cross_kv["k"], cross_kv["v"], positions,
                             cross_kv["pos"], causal=False, window=None,
                             attn_softcap=cfg.attn_softcap, impl=impl,
                             kv_chunk=kv_chunk, remat=remat)
        return out.reshape(B, S, nq * hd) @ p["wo"], cache

    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bk" in p:
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    k = aconstrain(k.reshape(B, S, nkv, hd), ("batch", None, "model", None))
    v = aconstrain(v.reshape(B, S, nkv, hd), ("batch", None, "model", None))

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window if kind == ATTN_LOCAL else None

    new_cache = cache
    if cache is not None:
        if S == 1:
            new_cache = _cache_write_decode(cache, k, v, positions)
        else:
            new_cache = _cache_write_prefill(cache, k, v, positions)
        k_all = new_cache["k"]
        v_all = new_cache["v"]
        kv_pos = new_cache["pos"]
    else:
        k_all, v_all, kv_pos = k, v, positions

    out = _sdpa_dispatch(q, k_all, v_all, positions, kv_pos, causal=causal,
                         window=window, attn_softcap=cfg.attn_softcap,
                         impl=impl, kv_chunk=kv_chunk, remat=remat)
    out = aconstrain(out, ("batch", None, "model", None))
    return out.reshape(B, S, nq * hd) @ p["wo"], new_cache


def _sdpa_dispatch(q, k, v, q_pos, kv_pos, *, causal, window, attn_softcap,
                   impl, kv_chunk, remat):
    if impl == "pallas":
        from repro.kernels import ops
        return ops.flash_attention(q, k, v, q_pos, kv_pos, causal=causal,
                                   window=window, softcap=attn_softcap)
    return sdpa_chunked(q, k, v, q_pos, kv_pos, causal=causal, window=window,
                        attn_softcap=attn_softcap, kv_chunk=kv_chunk,
                        remat=remat)

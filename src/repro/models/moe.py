"""Mixture-of-Experts layer (grok-1: 8e top-2, olmoe: 64e top-8).

Two compute modes:

* ``dense``  — every expert runs on every token; outputs are combined with
  router weights. Exact, simple, and the *paper-faithful baseline* for the
  roofline table (the FLOP overcount factor E/k is reported there). This is
  also what several production JAX frameworks ship as the non-kernel path.
* ``sorted`` — dropless-style dispatch: tokens are sorted by expert id and
  each expert processes a fixed-capacity contiguous block (scan over
  experts). FLOPs ~ k/E of dense mode (+capacity slack); used by the §Perf
  hillclimb. Overflowing tokens beyond capacity are dropped from the expert
  (they keep their residual path), underflow is padded — standard
  capacity-factor semantics.

Router: softmax over expert logits, top-k, renormalized combine weights,
plus the standard load-balancing auxiliary loss (Switch/OLMoE style).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import aconstrain, logical_size
from repro.models.layers import dense_init


def init_moe(key, cfg, dtype=jnp.float32):
    e = cfg.moe
    d, f = cfg.d_model, e.d_expert
    ks = jax.random.split(key, 4)
    glu = cfg.mlp_type in ("swiglu", "geglu")

    def stack(k, d_in, d_out):
        kk = jax.random.split(k, e.num_experts)
        return jnp.stack([dense_init(ki, d_in, d_out, dtype) for ki in kk])

    p = {"router": dense_init(ks[0], d, e.num_experts, dtype),
         "w_up": stack(ks[2], d, f),
         "w_down": stack(ks[3], f, d)}
    if glu:
        p["w_gate"] = stack(ks[1], d, f)
    return p


def _expert_ffn(p_e, x, mlp_type: str):
    """x: [..., d]; p_e: single expert's params (leading expert dim removed)."""
    if "w_gate" in p_e:
        gate = x @ p_e["w_gate"]
        act = jax.nn.silu(gate) if mlp_type == "swiglu" else jax.nn.gelu(gate, approximate=True)
        return (act * (x @ p_e["w_up"])) @ p_e["w_down"]
    return jax.nn.gelu(x @ p_e["w_up"], approximate=True) @ p_e["w_down"]


def router_topk(p, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (combine [T,k], expert_idx [T,k] int32, aux_loss scalar).

    x: [T, d] flattened tokens.
    """
    e = cfg.moe
    logits = (x @ p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    combine, idx = jax.lax.top_k(probs, e.experts_per_token)  # [T, k]
    combine = combine / jnp.maximum(combine.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss: E * sum_e f_e * P_e
    T = x.shape[0]
    onehot = jax.nn.one_hot(idx, e.num_experts, dtype=jnp.float32)  # [T,k,E]
    f_e = onehot.sum((0, 1)) / (T * e.experts_per_token)
    p_e = probs.mean(0)
    aux = e.num_experts * jnp.sum(f_e * p_e)
    return combine.astype(x.dtype), idx.astype(jnp.int32), aux


def moe_dense(p, x, cfg):
    """Dense mode. x: [B, S, d] -> (y, aux_loss)."""
    B, S, d = x.shape
    xt = aconstrain(x.reshape(B * S, d), ("batch", None))
    combine, idx, aux = router_topk(p, xt, cfg)
    e = cfg.moe
    # weight per expert per token: sum combine where idx==e  -> [T, E]
    w = jnp.zeros((xt.shape[0], e.num_experts), x.dtype)
    w = w.at[jnp.arange(xt.shape[0])[:, None], idx].add(combine)

    def one(p_e):
        return _expert_ffn(p_e, xt, cfg.mlp_type)            # [T, d]

    # scan over experts to keep the HLO body small & the intermediate bounded
    def body(acc, pe_we):
        p_e, w_e = pe_we
        return acc + one(p_e) * w_e[:, None], None

    acc0 = jnp.zeros_like(xt)
    experts = {k: v for k, v in p.items() if k != "router"}
    (y, _) = jax.lax.scan(body, acc0, (experts, w.T))
    return y.reshape(B, S, d), aux


def moe_sorted(p, x, cfg, capacity_factor: float = 1.25,
               n_groups: int = 1):
    """Sort-based dropless-style mode: FLOPs ~ k/E of dense (+slack).

    Tokens are replicated k times, sorted by assigned expert, and each expert
    consumes a fixed-size contiguous block of the sorted stream (capacity
    C = ceil(T*k/E * cf)). Tokens landing beyond their expert's capacity are
    dropped (residual path keeps them).

    n_groups > 1 splits the token stream into independent dispatch groups
    (GShard-style): with the group axis sharded over ('pod','data'), the
    argsort/gather/scatter stay device-local instead of sorting a globally
    sharded token axis (which forces collectives) — §Perf hillclimb 1 iter 2.
    """
    B, S, d = x.shape
    e = cfg.moe
    k = e.experts_per_token
    T_all = B * S
    if T_all % n_groups:
        n_groups = 1
    G = n_groups
    Tg = T_all // G

    xt = x.reshape(G, Tg, d)
    if G > 1:
        xt = aconstrain(xt, ("batch", None, None))
    combine, idx, aux = router_topk(p, xt.reshape(T_all, d), cfg)
    combine = combine.reshape(G, Tg, k)
    idx = idx.reshape(G, Tg, k)

    C = int(-(-Tg * k * capacity_factor // e.num_experts))

    def dispatch(xt_g, comb_g, idx_g):
        """Per-group index plumbing (device-local when groups are sharded)."""
        flat_exp = idx_g.reshape(-1)                          # [Tg*k]
        flat_tok = jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)
        flat_w = comb_g.reshape(-1)
        order = jnp.argsort(flat_exp, stable=True)
        sexp, stok, sw = flat_exp[order], flat_tok[order], flat_w[order]
        pos = jnp.arange(Tg * k) - jnp.searchsorted(sexp, sexp, side="left")
        keep = pos < C
        dest = jnp.where(keep, sexp * C + pos, e.num_experts * C)
        buf = jnp.zeros((e.num_experts * C + 1, d), x.dtype)
        buf = buf.at[dest].set(xt_g[stok], mode="drop")
        w = jnp.zeros((e.num_experts * C + 1,), x.dtype).at[dest].set(sw, mode="drop")
        tok = jnp.full((e.num_experts * C + 1,), Tg, jnp.int32).at[dest].set(stok, mode="drop")
        return (buf[:-1].reshape(e.num_experts, C, d), w[:-1], tok[:-1])

    xb, buf_w, buf_tok = jax.vmap(dispatch)(xt, combine, idx)  # [G,E,C,d]...

    # expert compute: EXPERT-PARALLEL — the dispatch buffer is resharded from
    # group-parallel to expert-parallel (all-to-all), each model shard runs
    # only its E/|model| experts, and the result is resharded back. When E
    # does not divide the TP axis (grok: E=8 < 16) fall back to sharding the
    # feature dim so the capacity buffers never replicate (hillclimb 1 note).
    exp_spec = ("batch", "model", None, None)
    if e.num_experts % max(logical_size("model"), 1):
        exp_spec = ("batch", None, None, "model")
    xb = aconstrain(xb, exp_spec)
    glu = "w_gate" in p
    up = jnp.einsum("gecd,edf->gecf", xb, p["w_up"])
    if glu:
        gate = jnp.einsum("gecd,edf->gecf", xb, p["w_gate"])
        act = (jax.nn.silu(gate) if cfg.mlp_type == "swiglu"
               else jax.nn.gelu(gate, approximate=True))
        hidden = act * up
    else:
        hidden = jax.nn.gelu(up, approximate=True)
    hidden = aconstrain(hidden, exp_spec)
    yb = jnp.einsum("gecf,efd->gecd", hidden, p["w_down"])
    yb = aconstrain(yb, exp_spec)
    yb = yb.reshape(G, e.num_experts * C, d) * buf_w[..., None]

    def combine_back(yb_g, tok_g):
        out = jnp.zeros((Tg + 1, d), x.dtype).at[tok_g].add(yb_g, mode="drop")
        return out[:-1]

    y = jax.vmap(combine_back)(yb, buf_tok)                   # [G, Tg, d]
    return y.reshape(B, S, d), aux


def moe(p, x, cfg, mode: str = "dense"):
    if mode == "sorted":
        return moe_sorted(p, x, cfg)
    if mode == "sorted_grouped":
        # group count chosen so groups shard over ('pod','data') and stay
        # large enough for balanced capacity (>= 2048 tokens per group)
        T = x.shape[0] * x.shape[1]
        n_groups = 1
        for g in (64, 32, 16, 8, 4, 2):
            if T % g == 0 and T // g >= 2048:
                n_groups = g
                break
        return moe_sorted(p, x, cfg, n_groups=n_groups)
    return moe_dense(p, x, cfg)

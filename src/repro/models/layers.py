"""Shared neural-net building blocks (pure functional JAX).

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of `init_*` / apply functions. Compute dtype follows the input; params
are created in `param_dtype`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * (1.0 / d) ** 0.5).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype=dtype)}  # gemma-style (1+scale)


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


def init_norm(cfg, d=None, dtype=jnp.float32):
    d = d if d is not None else cfg.d_model
    return init_layernorm(d, dtype) if cfg.norm == "layernorm" else init_rmsnorm(d, dtype)


def apply_norm(cfg, p, x):
    return layernorm(p, x) if "bias" in p else rmsnorm(p, x)


# ---------------------------------------------------------------------------
# Soft-capping (gemma2 / grok)
# ---------------------------------------------------------------------------
def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta: float = 10000.0):
    """x: [..., seq, heads, head_dim]; positions: [..., seq] int32."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]                       # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# MLPs: swiglu / geglu / gelu
# ---------------------------------------------------------------------------
def init_mlp(key, cfg, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, d, f, dtype),
                "w_up": dense_init(k2, d, f, dtype),
                "w_down": dense_init(k3, f, d, dtype)}
    return {"w_up": dense_init(k1, d, f, dtype),
            "w_down": dense_init(k2, f, d, dtype)}


def mlp(p, x, mlp_type: str):
    if mlp_type in ("swiglu", "geglu"):
        gate = x @ p["w_gate"]
        act = jax.nn.silu(gate) if mlp_type == "swiglu" else jax.nn.gelu(gate, approximate=True)
        return (act * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"], approximate=True) @ p["w_down"]


# ---------------------------------------------------------------------------
# Causal depthwise temporal conv (Griffin / xLSTM front conv)
# ---------------------------------------------------------------------------
def init_conv1d(key, width: int, kernel: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (kernel, width)) * (1.0 / kernel) ** 0.5).astype(dtype),
            "b": jnp.zeros((width,), dtype=dtype)}


def causal_conv1d(p, x, state=None):
    """Depthwise causal conv. x: [B, S, W]. state: [B, K-1, W] trailing inputs.
    Returns (y, new_state)."""
    k = p["w"].shape[0]
    if state is None:
        state = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), dtype=x.dtype)
    xin = jnp.concatenate([state, x], axis=-2)           # [B, S+K-1, W]
    y = sum(xin[..., i:i + x.shape[-2], :] * p["w"][i] for i in range(k))
    y = y + p["b"]
    new_state = xin[..., -(k - 1):, :] if k > 1 else state
    return y.astype(x.dtype), new_state

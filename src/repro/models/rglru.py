"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  x -> [gate branch: GeLU(W_g x)]
           -> [rec branch: W_x x -> causal conv1d -> RG-LRU]
        y = W_out (gate * rec)

RG-LRU cell (eq. 1-4 of the Griffin paper):
    r_t = sigmoid(W_a x_t)                      recurrence gate
    i_t = sigmoid(W_i x_t)                      input gate
    a_t = exp(-c * softplus(Lambda) * r_t)      in (0,1), c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses a parallel associative scan over time (the linear
recurrence (a,b) o (a',b') = (a a', a' b + b') is associative); decode is the
one-step update. A Pallas TPU kernel for the scan lives in
kernels/rglru_scan.py; this module is the pure-jnp reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import aconstrain
from repro.models.layers import causal_conv1d, dense_init, init_conv1d

_C = 8.0


def init_rglru(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "w_gate": dense_init(ks[0], d, w, dtype),
        "w_x": dense_init(ks[1], d, w, dtype),
        "conv": init_conv1d(ks[2], w, cfg.conv_kernel, dtype),
        "w_a": dense_init(ks[3], w, w, dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        # Lambda init so that a ~ U(0.9, 0.999)^(1/c)-ish (paper App. A)
        "lam": jnp.linspace(0.5, 4.0, w).astype(dtype),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _gates(p, u):
    """u: [..., w] (post-conv). Returns (log_a, beta*i*u) in fp32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return log_a, beta * i * uf


def lru_scan(log_a, b):
    """Parallel linear recurrence h_t = a_t h_{t-1} + b_t over axis -2.

    log_a, b: [B, S, W] fp32. Returns h: [B, S, W] fp32.
    """
    def combine(x, y):
        la1, b1 = x
        la2, b2 = y
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b), axis=-2)
    return h


def rglru_block(p, x, cfg, state=None, impl: str = "jnp"):
    """x: [B, S, d]. state: None or {"h": [B,W], "conv": [B,K-1,W]}.

    Returns (y [B,S,d], new_state).
    """
    gate = aconstrain(jax.nn.gelu(x @ p["w_gate"], approximate=True),
                      ("batch", None, "model"))
    u = aconstrain(x @ p["w_x"], ("batch", None, "model"))
    conv_state = None if state is None else state["conv"]
    u, new_conv = causal_conv1d(p["conv"], u, conv_state)

    log_a, b = _gates(p, u)
    if state is not None and x.shape[1] == 1:
        # decode: single-step update
        h_prev = state["h"].astype(jnp.float32)
        h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
        h_seq = h[:, None]
        new_h = h
    else:
        if impl == "pallas":
            from repro.kernels import ops
            h_seq = ops.rglru_scan(log_a, b)
        else:
            h_seq = lru_scan(log_a, b)
        if state is not None:
            h0 = state["h"].astype(jnp.float32)
            # fold the incoming state into the whole scan: h_t += (prod a) h0
            cum = jnp.cumsum(log_a, axis=1)
            h_seq = h_seq + jnp.exp(cum) * h0[:, None]
        new_h = h_seq[:, -1]

    rec = h_seq.astype(x.dtype)
    y = (gate * rec) @ p["w_out"]
    new_state = None
    if state is not None:
        new_state = {"h": new_h.astype(state["h"].dtype), "conv": new_conv}
    return y, new_state


def init_rglru_state(cfg, batch: int, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_kernel - 1, w), dtype)}

"""High-level model API: train / prefill / decode step builders used by the
launcher, the dry-run, the FL runtime, and the tests.

All steps are pure jittable functions; distribution is applied by the caller
via in_shardings/out_shardings (launch/dryrun.py, launch/train.py).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.optim.optimizers import Optimizer, clip_by_global_norm


def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    return tfm.init_params(key, cfg, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    return tfm.init_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
def make_loss_fn(cfg: ModelConfig, *, impl="jnp", kv_chunk=1024, remat=False):
    def loss(params, batch):
        return tfm.loss_fn(params, cfg, batch, impl=impl, kv_chunk=kv_chunk,
                           remat=remat)
    return loss


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *, impl="jnp",
                    kv_chunk=1024, remat=False, clip_norm: float = 1.0,
                    grad_weight: bool = False):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_weight: if True, batch may carry "example_weight" [B] multiplying
    per-example losses — this is how GenFV's rho_n*kappa weighting enters the
    jitted hot loop (DESIGN.md §4).
    """
    loss_fn = make_loss_fn(cfg, impl=impl, kv_chunk=kv_chunk, remat=remat)

    def step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        grads, gn = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gn}
        return params, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, *, impl="jnp", kv_chunk=1024,
                      long_window: Optional[int] = None):
    """prefill(params, cache, batch) -> (last_logits [B,V], cache)."""

    def prefill(params, cache, batch):
        hidden, cache, _ = tfm.forward(params, cfg, batch, cache=cache,
                                       impl=impl, kv_chunk=kv_chunk,
                                       long_window=long_window,
                                       logits_mode="hidden")
        logits = tfm.unembed(params, cfg, hidden[:, -1:])
        return logits[:, 0], cache

    return prefill


def make_decode_step(cfg: ModelConfig, *, impl="jnp", kv_chunk=1024,
                     long_window: Optional[int] = None):
    """decode(params, cache, tokens [B,1], positions [B,1])
    -> (logits [B,V], cache). ONE new token against the existing cache."""

    def decode(params, cache, tokens, positions):
        batch = {"tokens": tokens, "positions": positions}
        hidden, cache, _ = tfm.forward(params, cfg, batch, cache=cache,
                                       impl=impl, kv_chunk=kv_chunk,
                                       long_window=long_window,
                                       logits_mode="hidden")
        logits = tfm.unembed(params, cfg, hidden)
        return logits[:, 0], cache

    return decode


def greedy_generate(cfg, params, prompt, steps: int, *, impl="jnp",
                    max_len: Optional[int] = None, dtype=jnp.float32):
    """Reference generation loop (prefill + greedy decode). Test/demo helper."""
    B, S = prompt.shape
    max_len = max_len or (S + steps)
    cache = init_cache(cfg, B, max_len, dtype)
    prefill = jax.jit(make_prefill_step(cfg, impl=impl))
    decode = jax.jit(make_decode_step(cfg, impl=impl))
    logits, cache = prefill(params, cache, {"tokens": prompt})
    out = [jnp.argmax(logits, -1)]
    pos = jnp.full((B, 1), S, jnp.int32)
    for _ in range(steps - 1):
        logits, cache = decode(params, cache, out[-1][:, None], pos)
        out.append(jnp.argmax(logits, -1))
        pos = pos + 1
    return jnp.stack(out, axis=1)

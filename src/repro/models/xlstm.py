"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory with hidden-to-hidden recurrence).

mLSTM block (pre-LN residual):
    x -> up-proj to 2*inner (branches u, z)
    u -> causal conv -> q,k,v heads -> mLSTM cell -> per-head groupnorm
    y = down-proj( cell_out * silu(z) )

mLSTM cell with exponential gating + stabilizer m (paper eq. 19-27):
    C_t = f' C_{t-1} + i' v k^T      n_t = f' n_{t-1} + i' k
    h_t = C_t q / max(|n_t . q|, 1)
    f' = exp(ftilde + m_{t-1} - m_t), i' = exp(itilde - m_t),
    m_t = max(ftilde + m_{t-1}, itilde)

Training/prefill runs the cell as a `lax.scan` over time (exact recurrent
form — the paper-faithful baseline; a chunkwise-parallel variant is a §Perf
item). Decode is the one-step update. sLSTM cannot be parallelized over time
(nonlinear h->h recurrence) and always scans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.autoshard import aconstrain
from repro.models.layers import (causal_conv1d, dense_init, init_conv1d,
                                 init_layernorm, layernorm)


def _inner(cfg):
    return int(cfg.d_model * cfg.proj_factor)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    inner = _inner(cfg)
    h = cfg.num_heads
    hd = inner // h
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], d, inner, dtype),
        "w_z": dense_init(ks[1], d, inner, dtype),
        "conv": init_conv1d(ks[2], inner, cfg.conv_kernel, dtype),
        "wq": dense_init(ks[3], inner, inner, dtype),
        "wk": dense_init(ks[4], inner, inner, dtype),
        "wv": dense_init(ks[5], inner, inner, dtype),
        # gates are per-head scalars computed from the conv'd branch
        "w_if": dense_init(ks[6], inner, 2 * h, dtype),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.linspace(3.0, 6.0, h)]).astype(dtype),
        "norm": init_layernorm(hd, dtype),
        "w_down": dense_init(ks[7], inner, d, dtype),
    }


def _mlstm_cell_step(carry, xs):
    """carry: (C [B,h,hd,hd], n [B,h,hd], m [B,h]); xs: per-step tensors."""
    C, n, m = carry
    q, k, v, it, ft = xs                   # q,k,v: [B,h,hd]; it,ft: [B,h]
    m_new = jnp.maximum(ft + m, it)
    fp = jnp.exp(ft + m - m_new)[..., None]           # [B,h,1]
    ip = jnp.exp(it - m_new)[..., None]
    C = fp[..., None] * C + ip[..., None] * (v[..., :, None] * k[..., None, :])
    n = fp * n + ip * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    # stabilized normalizer max(|n.q|, exp(-m)) == unstabilized max(|n*.q|, 1)
    # — exactly matches the chunkwise-parallel form in mlstm_seq
    den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    h_out = num / den
    return (C, n, m_new), h_out


def mlstm_seq(q, k, v, it, ft, state, chunk: int = 256):
    """Chunkwise-parallel mLSTM (TPU-native adaptation, DESIGN.md §3):

    A per-timestep scan of the matrix memory C [B,h,hd,hd] is exact but
    stores C at every step for BPTT (TB-scale at 4k context). Instead the
    sequence is split into chunks; the (C, n, m) state crosses chunk
    boundaries and *within* a chunk the output is the stabilized quadratic
    form — dense [chunk x chunk] matmuls that run on the MXU and need no
    per-step state. Exactly equal to the recurrent cell (tests assert it).

    q/k/v: [B,S,h,hd] (q,k pre-scaled); it/ft: [B,S,h] fp32 (ft = log f).
    Returns (h [B,S,h,hd], (C,n,m) final state).
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z3 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, z4) for a in (q, k, v))
        # padded steps: f = 1 (log f = 0), i = -inf -> state passes through
        it = jnp.pad(it, z3, constant_values=-1e30)
        ft = jnp.pad(ft, z3, constant_values=0.0)
    n_ch = (S + pad) // chunk

    def to_chunks(a):
        return a.reshape(B, n_ch, chunk, *a.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(to_chunks, (q, k, v, it, ft))

    def chunk_body(carry, xs):
        C_in, n_in, m_in = carry                       # [B,H,hd,hd], [B,H,hd], [B,H]
        q_i, k_i, v_i, i_i, f_i = xs                   # [B,c,H,hd], [B,c,H]
        F = jnp.cumsum(f_i, axis=1)                    # [B,c,H] inclusive logf sums
        c_s = i_i - F                                  # i_s - F_s
        m_loc = jax.lax.cummax(c_s, axis=1)
        m_t = F + jnp.maximum(m_in[:, None], m_loc)    # running max per step
        # intra-chunk stabilized decay: d_ts = exp(F_t - F_s + i_s - m_t)
        logd = (F[:, :, None] - F[:, None, :] + i_i[:, None, :]
                - m_t[:, :, None])                     # [B,t,s,H]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        logd = jnp.where(tri[None, :, :, None], logd, -jnp.inf)
        d = jnp.exp(logd)
        # inter-chunk scale: e_t = exp(F_t + m_in - m_t)
        e_t = jnp.exp(F + m_in[:, None] - m_t)         # [B,c,H]

        s_qk = jnp.einsum("bthd,bshd->bhts", q_i, k_i)  # [B,H,t,s]
        w = s_qk * d.transpose(0, 3, 1, 2)
        intra_num = jnp.einsum("bhts,bshd->bthd", w, v_i)
        intra_den = jnp.sum(w, axis=-1).transpose(0, 2, 1)        # [B,c,H]
        inter_num = jnp.einsum("bhij,bthj->bthi", C_in, q_i) * e_t[..., None]
        inter_den = jnp.einsum("bhj,bthj->bth", n_in, q_i) * e_t
        den = jnp.maximum(jnp.abs(inter_den + intra_den), jnp.exp(-m_t))
        h = (inter_num + intra_num) / den[..., None]   # [B,c,H,hd]

        # chunk-end state (stabilized at m_out = m_t[last])
        m_out = m_t[:, -1]
        g_s = jnp.exp(F[:, -1:, :] - F + i_i - m_out[:, None])   # [B,c,H]
        C_out = (jnp.exp(F[:, -1] + m_in - m_out)[..., None, None] * C_in
                 + jnp.einsum("bsh,bshd,bshe->bhde", g_s, v_i, k_i))
        n_out = (jnp.exp(F[:, -1] + m_in - m_out)[..., None] * n_in
                 + jnp.einsum("bsh,bshd->bhd", g_s, k_i))
        return (C_out, n_out, m_out), h

    body = jax.checkpoint(chunk_body)
    (C, n, m), hs = jax.lax.scan(body, state, (qc, kc, vc, ic, fc))
    hs = hs.swapaxes(0, 1).reshape(B, S + pad, H, hd)
    return hs[:, :S], (C, n, m)


def mlstm_block(p, x, cfg, state=None):
    """x: [B,S,d] -> (y, new_state). state: (C, n, m, conv) or None."""
    B, S, _ = x.shape
    inner = _inner(cfg)
    h = cfg.num_heads
    hd = p["norm"]["scale"].shape[0]
    u = aconstrain(x @ p["w_up"], ("batch", None, "model"))
    z = aconstrain(x @ p["w_z"], ("batch", None, "model"))
    conv_state = None if state is None else state[3]
    uc, new_conv = causal_conv1d(p["conv"], jax.nn.silu(u), conv_state)

    q = (uc @ p["wq"]).reshape(B, S, h, hd).astype(jnp.float32) * (hd ** -0.5)
    k = (uc @ p["wk"]).reshape(B, S, h, hd).astype(jnp.float32) * (hd ** -0.5)
    v = (u @ p["wv"]).reshape(B, S, h, hd).astype(jnp.float32)
    gates = (uc @ p["w_if"]).astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    it, ft = gates[..., :h], jax.nn.log_sigmoid(gates[..., h:])

    if state is None:
        C0 = jnp.zeros((B, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, h, hd), jnp.float32)
        m0 = jnp.zeros((B, h), jnp.float32)
        cell_state = (C0, n0, m0)
    else:
        cell_state = (state[0], state[1], state[2])

    if S == 1 and state is not None:
        (C, n, m), h_out = _mlstm_cell_step(
            cell_state, (q[:, 0], k[:, 0], v[:, 0], it[:, 0], ft[:, 0]))
        hs = h_out[:, None]
    else:
        hs, (C, n, m) = mlstm_seq(q, k, v, it, ft, cell_state)

    hs = layernorm(p["norm"], hs)                     # per-head groupnorm
    y = (hs.reshape(B, S, inner).astype(x.dtype) * jax.nn.silu(z)) @ p["w_down"]
    return y, (C, n, m, new_conv)


def init_mlstm_state(cfg, batch: int, dtype=jnp.float32):
    inner = _inner(cfg)
    h = cfg.num_heads
    hd = inner // h
    return (jnp.zeros((batch, h, hd, hd), jnp.float32),
            jnp.zeros((batch, h, hd), jnp.float32),
            jnp.zeros((batch, h), jnp.float32),
            jnp.zeros((batch, cfg.conv_kernel - 1, inner), dtype))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    inner = _inner(cfg)
    h = cfg.num_heads
    hd = inner // h
    ks = jax.random.split(key, 4)
    # input projections for (z, i, f, o) and block-diagonal recurrent mats
    return {
        "w_in": dense_init(ks[0], d, 4 * inner, dtype),
        "r": (jax.random.normal(ks[1], (4, h, hd, hd)) * (hd ** -0.5)).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros((2 * inner,)), jnp.linspace(3.0, 6.0, inner),
             jnp.zeros((inner,))]).astype(dtype),
        "norm": init_layernorm(inner, dtype),
        "w_down": dense_init(ks[2], inner, d, dtype),
    }


def _slstm_step(p, carry, x_t):
    """carry: (c, n, m, h) each [B, inner] fp32; x_t: [B, 4*inner]."""
    c, n, m, h = carry
    B = c.shape[0]
    nh = p["r"].shape[1]
    hd = p["r"].shape[-1]
    hr = h.reshape(B, nh, hd)
    rec = jnp.einsum("ghij,bhj->gbhi", p["r"].astype(jnp.float32), hr)
    rec = rec.reshape(4, B, nh * hd)
    pre = x_t.astype(jnp.float32) + p["b"].astype(jnp.float32)
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt + rec[0])
    it = it + rec[1]
    ft = jax.nn.log_sigmoid(ft + rec[2])
    ot = jax.nn.sigmoid(ot + rec[3])
    m_new = jnp.maximum(ft + m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(ft + m - m_new)
    c = fp * c + ip * zt
    n = fp * n + ip
    h_new = ot * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h_new), h_new


def slstm_block(p, x, cfg, state=None):
    """x: [B,S,d] -> (y, new_state)."""
    B, S, _ = x.shape
    inner = _inner(cfg)
    xin = aconstrain(x @ p["w_in"], ("batch", None, "model"))
    if state is None:
        z = jnp.zeros((B, inner), jnp.float32)
        state = (z, z, z, z)
    if S == 1:
        new_state, h = _slstm_step(p, state, xin[:, 0])
        hs = h[:, None]
    else:
        def step(carry, x_t):
            return _slstm_step(p, carry, x_t)
        new_state, hs = jax.lax.scan(step, state, xin.transpose(1, 0, 2))
        hs = hs.transpose(1, 0, 2)
    hs = layernorm(p["norm"], hs).astype(x.dtype)
    y = hs @ p["w_down"]
    return y, new_state


def init_slstm_state(cfg, batch: int, dtype=jnp.float32):
    inner = _inner(cfg)
    z = jnp.zeros((batch, inner), jnp.float32)
    return (z, z, z, z)

"""Model zoo: composable blocks + unified transformer for the 10 assigned
architectures, plus the paper's ResNet-18 CNN (models/cnn.py)."""

"""ResNet-18-style CNN for the paper-faithful GenFV experiments (Sec. VI:
ResNet-18 on CIFAR-10/100/GTSRB).

GroupNorm is used instead of BatchNorm: batch statistics are ill-defined
under federated non-IID client batches (standard practice in FL work — see
e.g. FedBN literature); this is recorded as a deviation in DESIGN.md. The
topology (2-2-2-2 basic blocks, 64-128-256-512 widths, 3x3 stem for 32x32
inputs) matches the CIFAR variant of ResNet-18.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def _conv_init(key, k, c_in, c_out):
    fan_in = k * k * c_in
    return jax.random.normal(key, (k, k, c_in, c_out)) * (2.0 / fan_in) ** 0.5


def conv2d(w, x, stride: int = 1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def groupnorm(p, x, groups: int = 8, eps: float = 1e-5):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mu = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return xg.reshape(B, H, W, C) * p["scale"] + p["bias"]


def _gn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _block_init(key, c_in, c_out, stride):
    ks = jax.random.split(key, 3)
    p = {"conv1": _conv_init(ks[0], 3, c_in, c_out), "gn1": _gn_init(c_out),
         "conv2": _conv_init(ks[1], 3, c_out, c_out), "gn2": _gn_init(c_out)}
    if stride != 1 or c_in != c_out:
        p["proj"] = _conv_init(ks[2], 1, c_in, c_out)
        p["gn_proj"] = _gn_init(c_out)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(groupnorm(p["gn1"], conv2d(p["conv1"], x, stride)))
    h = groupnorm(p["gn2"], conv2d(p["conv2"], h))
    if "proj" in p:
        x = groupnorm(p["gn_proj"], conv2d(p["proj"], x, stride))
    return jax.nn.relu(x + h)


def init_cnn(key, cfg) -> Dict[str, Any]:
    """cfg: CNNConfig (configs/genfv_cifar.py)."""
    w0 = int(cfg.stem_width * cfg.width_mult)
    widths = [w0, 2 * w0, 4 * w0, 8 * w0]
    ks = jax.random.split(key, 2 + sum(cfg.stage_blocks))
    params: Dict[str, Any] = {
        "stem": _conv_init(ks[0], 3, cfg.channels, w0),
        "gn_stem": _gn_init(w0),
        "stages": [],
    }
    i = 1
    c_in = w0
    for s, (c_out, n) in enumerate(zip(widths, cfg.stage_blocks)):
        stage = []
        for b in range(n):
            stride = 2 if (b == 0 and s > 0) else 1
            stage.append(_block_init(ks[i], c_in, c_out, stride))
            c_in = c_out
            i += 1
        params["stages"].append(stage)
    params["head"] = {
        "w": jax.random.normal(ks[i], (c_in, cfg.num_classes)) * (1.0 / c_in) ** 0.5,
        "b": jnp.zeros((cfg.num_classes,)),
    }
    return params


def cnn_forward(params, cfg, images):
    """images: [B, H, W, C] float. Returns logits [B, num_classes]."""
    x = jax.nn.relu(groupnorm(params["gn_stem"], conv2d(params["stem"], images)))
    for s, stage in enumerate(params["stages"]):
        for b, bp in enumerate(stage):
            stride = 2 if (b == 0 and s > 0) else 1
            x = _block_apply(bp, x, stride)
    x = x.mean((1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def cnn_loss(params, cfg, batch):
    """batch: images [B,H,W,C], labels [B] int32, optional weights [B]."""
    logits = cnn_forward(params, cfg, batch["images"])
    ll = jax.nn.log_softmax(logits.astype(jnp.float32))
    ce = -jnp.take_along_axis(ll, batch["labels"][:, None], axis=-1)[:, 0]
    w = batch.get("weights")
    if w is None:
        return ce.mean(), logits
    return jnp.sum(ce * w) / jnp.maximum(jnp.sum(w), 1e-9), logits


def cnn_accuracy(params, cfg, images, labels):
    logits = cnn_forward(params, cfg, images)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))

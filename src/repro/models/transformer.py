"""Unified model: decoder-only / encoder-decoder / VLM backbones for all
assigned architectures, built from the block zoo (attention, MoE, RG-LRU,
xLSTM).

Heterogeneous layer patterns (gemma2 local/global, griffin rglru:attn 2:1,
xlstm 7:1) are executed as a `lax.scan` over *pattern groups*: one group =
one instance of cfg.pattern, parameters stacked over groups. This keeps the
HLO body to one pattern instance for any depth (42-64 layers), which bounds
compile time across the 80 dry-run combinations. Layers left over when the
pattern does not divide num_layers run unscanned ("remainder").

Caches (KV ring buffers / recurrent states) mirror the same group structure
so decode carries them through the scan.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, BLOCK_MLSTM,
                                BLOCK_RGLRU, BLOCK_SLSTM, ModelConfig)
from repro.distributed.autoshard import aconstrain
from repro.models import xlstm as xl
from repro.models.attention import attention, init_attention, init_kv_cache
from repro.models.layers import (apply_norm, dense_init, embed_init,
                                 init_mlp, init_norm, mlp, softcap)
from repro.models.moe import init_moe, moe
from repro.models.rglru import init_rglru, init_rglru_state, rglru_block

VISION_EMBED_DIM = 1024      # CLIP-ViT-L patch embedding width (llava stub)


# ---------------------------------------------------------------------------
# Per-layer init / apply, dispatched on kind
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ModelConfig, kind: str, dtype, cross: bool):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(cfg, dtype=dtype)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["attn"] = init_attention(ks[0], cfg, dtype)
        if cross:
            p["lnx"] = init_norm(cfg, dtype=dtype)
            p["cross"] = init_attention(ks[1], cfg, dtype)
        if cfg.moe is not None:
            p["ln2"] = init_norm(cfg, dtype=dtype)
            p["moe"] = init_moe(ks[2], cfg, dtype)
        elif cfg.d_ff > 0:
            p["ln2"] = init_norm(cfg, dtype=dtype)
            p["mlp"] = init_mlp(ks[2], cfg, dtype)
    elif kind == BLOCK_RGLRU:
        p["rec"] = init_rglru(ks[0], cfg, dtype)
        p["ln2"] = init_norm(cfg, dtype=dtype)
        p["mlp"] = init_mlp(ks[1], cfg, dtype)
    elif kind == BLOCK_MLSTM:
        p["cell"] = xl.init_mlstm(ks[0], cfg, dtype)
    elif kind == BLOCK_SLSTM:
        p["cell"] = xl.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _init_layer_cache(cfg, kind: str, batch: int, max_len: int, dtype,
                      cross: bool, enc_seq: int):
    c: Dict[str, Any] = {}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        c["kv"] = init_kv_cache(cfg, kind, batch, max_len, dtype)
        if cross:
            c["cross_kv"] = {
                "k": jnp.zeros((batch, enc_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, enc_seq, cfg.num_kv_heads, cfg.head_dim), dtype),
                "pos": jnp.zeros((batch, enc_seq), jnp.int32),
            }
    elif kind == BLOCK_RGLRU:
        c["rec"] = init_rglru_state(cfg, batch, dtype)
    elif kind == BLOCK_MLSTM:
        c["cell"] = xl.init_mlstm_state(cfg, batch, dtype)
    elif kind == BLOCK_SLSTM:
        c["cell"] = xl.init_slstm_state(cfg, batch, dtype)
    return c


def _apply_layer(p, x, cfg, kind: str, positions, cache, *, impl, kv_chunk,
                 cross: bool, decode: bool, long_window: Optional[int]):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        # long-context serving variant (gemma2): global layers fall back to
        # the sliding window so 500k decode stays sub-quadratic.
        eff_kind = kind
        if long_window is not None and kind == ATTN_GLOBAL:
            eff_kind = ATTN_LOCAL
        h = apply_norm(cfg, p["ln1"], x)
        h, kv = attention(p["attn"], h, cfg, eff_kind, positions,
                          cache=None if cache is None else cache["kv"],
                          impl=impl, kv_chunk=kv_chunk)
        if cache is not None:
            new_cache["kv"] = kv
        x = x + h
        if cross:
            h = apply_norm(cfg, p["lnx"], x)
            h, _ = attention(p["cross"], h, cfg, ATTN_GLOBAL, positions,
                             cross_kv=cache["cross_kv"], impl=impl,
                             kv_chunk=kv_chunk)
            x = x + h
        if "moe" in p:
            h = apply_norm(cfg, p["ln2"], x)
            h, aux_l = moe(p["moe"], h, cfg, mode=cfg_moe_mode(cfg))
            aux = aux + cfg.moe.router_aux_loss * aux_l
            x = x + h
        elif "mlp" in p:
            h = apply_norm(cfg, p["ln2"], x)
            x = x + mlp(p["mlp"], h, cfg.mlp_type)
    elif kind == BLOCK_RGLRU:
        h = apply_norm(cfg, p["ln1"], x)
        h, rec = rglru_block(p["rec"], h, cfg,
                             state=None if cache is None else cache["rec"],
                             impl=impl)
        if cache is not None:
            new_cache["rec"] = rec
        x = x + h
        h = apply_norm(cfg, p["ln2"], x)
        x = x + mlp(p["mlp"], h, cfg.mlp_type)
    elif kind == BLOCK_MLSTM:
        h = apply_norm(cfg, p["ln1"], x)
        h, st = xl.mlstm_block(p["cell"], h, cfg,
                               state=None if cache is None else cache["cell"])
        if cache is not None:
            new_cache["cell"] = st
        x = x + h
    elif kind == BLOCK_SLSTM:
        h = apply_norm(cfg, p["ln1"], x)
        h, st = xl.slstm_block(p["cell"], h, cfg,
                               state=None if cache is None else cache["cell"])
        if cache is not None:
            new_cache["cell"] = st
        x = x + h
    return x, new_cache, aux


# module-level override (set by perf experiments); "dense" is paper-baseline
_MOE_MODE = {"mode": "dense"}


def set_moe_mode(mode: str):
    _MOE_MODE["mode"] = mode


def cfg_moe_mode(cfg) -> str:
    return _MOE_MODE["mode"]


# ---------------------------------------------------------------------------
# Group structure
# ---------------------------------------------------------------------------
def _group_split(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    """(num_full_groups, remainder_kinds)."""
    plen = len(cfg.pattern)
    g = cfg.num_layers // plen
    rem = cfg.layer_kinds[g * plen:]
    return g, rem


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    vocab = cfg.padded_vocab_size
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, vocab, dtype)
    if cfg.modality == "vision":
        # llava projector: 2-layer MLP from CLIP width to d_model (trained)
        k1, k2 = jax.random.split(ks[2])
        params["frontend_proj"] = {
            "w1": dense_init(k1, VISION_EMBED_DIM, cfg.d_model, dtype),
            "w2": dense_init(k2, cfg.d_model, cfg.d_model, dtype),
        }

    cross = cfg.is_encdec
    G, rem = _group_split(cfg)

    def one_group(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return [_init_layer(kk[i], cfg, kind, dtype, cross)
                for i, kind in enumerate(cfg.pattern)]

    if G > 0:
        params["groups"] = jax.vmap(one_group)(jax.random.split(ks[3], G))
    params["rem"] = [_init_layer(k, cfg, kind, dtype, cross)
                     for k, kind in zip(jax.random.split(ks[4], max(len(rem), 1)), rem)]

    if cfg.is_encdec:
        enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encoder_layers,
                                      pattern=(ATTN_GLOBAL,))

        def enc_group(k):
            return [_init_layer(k, enc_cfg, ATTN_GLOBAL, dtype, False)]

        params["encoder"] = {
            "groups": jax.vmap(enc_group)(jax.random.split(ks[5], cfg.encoder_layers)),
            "final_norm": init_norm(cfg, dtype=dtype),
        }
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    """Decode cache matching the group structure."""
    cross = cfg.is_encdec
    G, rem = _group_split(cfg)

    def one(kind):
        return _init_layer_cache(cfg, kind, batch, max_len, dtype, cross,
                                 cfg.encoder_seq)

    cache: Dict[str, Any] = {}
    if G > 0:
        group = [one(kind) for kind in cfg.pattern]
        cache["groups"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (G,) + x.shape).copy(), group)
    cache["rem"] = [one(kind) for kind in rem]
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------
def _embed_tokens(params, cfg, tokens):
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _run_layers(params, x, cfg, positions, cache, *, impl, kv_chunk, remat,
                cross, decode, long_window):
    """Scan the pattern groups, then the remainder. Returns (x, cache, aux)."""
    G, rem = _group_split(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    if G > 0:
        has_cache = cache is not None

        def body(carry, xs):
            xc, aux = carry
            # sequence-parallel residual (Megatron-SP): the scan carry -- the
            # dominant saved activation for backward -- lives sharded over
            # ('batch', 'model' on seq); attention/mlp gather what they need
            # per layer. 16x smaller carries for +1 gather/reduce per layer.
            xc = aconstrain(xc, ("batch", "model", None))
            gp, gc = xs
            new_gc = []
            for i, kind in enumerate(cfg.pattern):
                ci = gc[i] if has_cache else None
                xc, nc, a = _apply_layer(
                    gp[i], xc, cfg, kind, positions, ci, impl=impl,
                    kv_chunk=kv_chunk, cross=cross, decode=decode,
                    long_window=long_window)
                new_gc.append(nc if has_cache else {})
                aux = aux + a
            xc = aconstrain(xc, ("batch", "model", None))
            return (xc, aux), new_gc

        if has_cache:
            if remat:
                body = jax.checkpoint(body)
            (x, aux_total), new_gcache = jax.lax.scan(
                body, (x, aux_total), (params["groups"], cache["groups"]))
            cache = dict(cache)
            cache["groups"] = new_gcache
        else:
            def body_nc(carry, gp):
                none_cache = [None] * len(cfg.pattern)
                new_carry, _ = body(carry, (gp, none_cache))
                return new_carry, None

            if remat:
                body_nc = jax.checkpoint(body_nc)
            (x, aux_total), _ = jax.lax.scan(body_nc, (x, aux_total),
                                             params["groups"])

    new_rem = []
    for i, kind in enumerate(rem):
        ci = cache["rem"][i] if cache is not None else None
        x, nc, a = _apply_layer(params["rem"][i], x, cfg, kind, positions, ci,
                                impl=impl, kv_chunk=kv_chunk, cross=cross,
                                decode=decode, long_window=long_window)
        new_rem.append(nc)
        aux_total = aux_total + a
    if cache is not None:
        cache["rem"] = new_rem
    return x, cache, aux_total


def encode(params, cfg, frames, *, impl="jnp", kv_chunk=1024):
    """Whisper encoder over (stubbed) frame embeddings [B, F, d]."""
    enc = params["encoder"]
    x = frames
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32)[None],
                           x.shape[:2])

    def body(carry, gp):
        xc, _ = carry
        h = apply_norm(cfg, gp[0]["ln1"], xc)
        h, _ = attention(gp[0]["attn"], h, cfg, ATTN_GLOBAL, pos, impl=impl,
                         kv_chunk=kv_chunk, causal=False)
        xc = xc + h
        h = apply_norm(cfg, gp[0]["ln2"], xc)
        xc = xc + mlp(gp[0]["mlp"], h, cfg.mlp_type)
        return (xc, 0.0), None

    (x, _), _ = jax.lax.scan(body, (x, 0.0), enc["groups"])
    return apply_norm(cfg, enc["final_norm"], x)


def build_cross_kv(params, cfg, enc_out):
    """Project encoder output into per-decoder-layer cross K/V."""
    B, F, _ = enc_out.shape
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32)[None], (B, F))

    def one(layer_p):
        k = (enc_out @ layer_p["cross"]["wk"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        v = (enc_out @ layer_p["cross"]["wv"]).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        return {"k": k, "v": v, "pos": pos}

    G, rem = _group_split(cfg)
    out = {}
    if G > 0:
        out["groups"] = [jax.vmap(one)(params["groups"][i])
                         for i in range(len(cfg.pattern))]
    out["rem"] = [one(params["rem"][i]) for i in range(len(rem))]
    return out


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            cache=None, impl: str = "jnp", kv_chunk: int = 1024,
            remat: bool = False, long_window: Optional[int] = None,
            logits_mode: str = "full"):
    """Returns (logits_or_hidden, new_cache, aux).

    batch keys: tokens [B,S]; optional positions [B,S];
    vision: patch_embeds [B,P,1024]; audio: frames [B,F,d].
    logits_mode: "full" -> [B,S,V] logits; "hidden" -> final hidden states.
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = aconstrain(_embed_tokens(params, cfg, tokens), ("batch", None, None))

    n_front = 0
    if cfg.modality == "vision" and "patch_embeds" in batch:
        pe = batch["patch_embeds"]
        fp = params["frontend_proj"]
        pe = jax.nn.gelu(pe @ fp["w1"], approximate=True) @ fp["w2"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        n_front = pe.shape[1]
        S = S + n_front

    if "positions" in batch:
        positions = batch["positions"]
        if n_front:
            fpos = jnp.broadcast_to(jnp.arange(n_front, dtype=jnp.int32)[None],
                                    (B, n_front))
            positions = jnp.concatenate([fpos, positions + n_front], axis=1)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    cross = cfg.is_encdec
    if cross and cache is None:
        # training path: run encoder, build per-layer cross kv on the fly
        enc_out = encode(params, cfg, batch["frames"], impl=impl,
                         kv_chunk=kv_chunk)
        cross_kv = build_cross_kv(params, cfg, enc_out)
        cache = _attach_cross(cfg, cross_kv, batch=B,
                              max_len=S, dtype=x.dtype, train=True)

    x, cache, aux = _run_layers(params, x, cfg, positions, cache, impl=impl,
                                kv_chunk=kv_chunk, remat=remat, cross=cross,
                                decode=(S == 1), long_window=long_window)
    x = apply_norm(cfg, params["final_norm"], x)
    if n_front:
        x = x[:, n_front:]
    if logits_mode == "hidden":
        return x, cache, aux
    logits = unembed(params, cfg, x)
    return logits, cache, aux


def unembed(params, cfg, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab_size != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab_size) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e9, logits)
    return logits


def _attach_cross(cfg, cross_kv, batch, max_len, dtype, train):
    """Build a cache pytree that carries only cross_kv (training encdec) or
    merge cross_kv into an existing decode cache."""
    G, rem = _group_split(cfg)
    cache: Dict[str, Any] = {}
    if train:
        # training: self-attn has no cache; represent each layer cache as
        # {"kv": None-free dict}? -> run without self cache: we instead pass
        # cache dicts containing only cross_kv and a fresh kv cache of S.
        full = init_cache(cfg, batch, max_len, dtype)
        if G > 0:
            for i in range(len(cfg.pattern)):
                full["groups"][i]["cross_kv"] = cross_kv["groups"][i]
        for i in range(len(rem)):
            full["rem"][i]["cross_kv"] = cross_kv["rem"][i]
        return full
    return cross_kv


# ---------------------------------------------------------------------------
# Loss (chunked-vocab cross entropy, never materializes [B,S,V] at once)
# ---------------------------------------------------------------------------
def chunked_xent(params, cfg, hidden, targets, mask, chunk: int = 256):
    """hidden: [B,S,d]; targets,mask: [B,S]. Mean masked CE in fp32."""
    B, S, d = hidden.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // chunk
    hs = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h, t, m = xs
        logits = unembed(params, cfg, h)                 # [B,chunk,V] fp32
        logits = aconstrain(logits, ("batch", None, "model"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        loss = (lse - ll) * m
        return (carry[0] + loss.sum(), carry[1] + m.sum()), None

    # checkpoint: without it the backward saves every chunk's [B,chunk,V]
    # fp32 logits (30 GiB/device at vocab 122k) — recompute one chunk instead
    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)),
                                 (hs, ts, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg, batch, *, impl="jnp", kv_chunk=1024, remat=False):
    hidden, _, aux = forward(params, cfg, batch, impl=impl, kv_chunk=kv_chunk,
                             remat=remat, logits_mode="hidden")
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(batch["targets"], jnp.float32)
    ce = chunked_xent(params, cfg, hidden, batch["targets"], mask)
    return ce + aux, {"ce": ce, "aux": aux}

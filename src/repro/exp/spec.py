"""Declarative experiment grids.

An `ExperimentSpec` names the sweep axes the paper's results actually
vary — strategy, scenario, Dirichlet alpha, seed, and the AIGC sampler's
step count (the SUBP4 quality/cost dial) — plus an override-variant
axis for anything else on `RunConfig` (planner backend, model size, ...).
`expand()` returns one frozen, registry-validated `RunConfig` per grid cell
in a deterministic order; validation runs eagerly at spec construction, so
a typo'd strategy name fails before any dataset is built or kernel traced.

`to_json()` is byte-deterministic across processes (sorted keys, plain
scalars only) — the guard tests/test_exp.py pins it the same way the
rush_hour cross-runner test pins the world.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence, Tuple

from repro.fl.rounds import RunConfig, run_payload

SPEC_SCHEMA = "repro.exp/spec/v1"

#: RunConfig fields owned by the grid axes — overriding them per-variant
#: would make a cell's coordinates ambiguous.
_AXIS_FIELDS = frozenset({"strategy", "scenario", "alpha", "seed",
                          "sampler_steps"})
#: "obs" is execution machinery (attach a tracer via Sweep(obs=...) or the
#: runner, not through a serialized spec): not a valid override.
_RUN_FIELDS = frozenset(
    f.name for f in dataclasses.fields(RunConfig)) - {"obs"}


def grid(**axes: Sequence) -> List[Dict[str, Any]]:
    """Ordered cartesian product of named axes.

    ``grid(dataset=("cifar10", "gtsrb"), alpha=(0.1, 1.0))`` yields the four
    dicts in nested order (later axes fastest). Deterministic: iteration
    follows keyword order, never hash order. The light-weight counterpart of
    `ExperimentSpec.expand()` for parameter loops that do not run FL rounds
    (benchmarks fig5/fig9).
    """
    cells: List[Dict[str, Any]] = [{}]
    for key, values in axes.items():
        cells = [dict(c, **{key: v}) for c in cells for v in values]
    return cells


def _freeze_overrides(overrides) -> Tuple[Tuple[Tuple[str, Any], ...], ...]:
    """Normalize a sequence of override dicts into hashable sorted tuples."""
    frozen = []
    for ov in (overrides if overrides else ({},)):
        items = sorted(dict(ov).items())
        for key, _ in items:
            if key in _AXIS_FIELDS:
                raise ValueError(
                    f"override {key!r} collides with a grid axis; sweep it "
                    f"via the {key}s axis instead")
            if key not in _RUN_FIELDS:
                raise ValueError(
                    f"unknown RunConfig field {key!r} in overrides; valid: "
                    f"{', '.join(sorted(_RUN_FIELDS))}")
        frozen.append(tuple(items))
    return tuple(frozen)


@dataclass(frozen=True)
class Cell:
    """One grid point: its coordinates plus the frozen RunConfig."""
    index: int
    strategy: str
    scenario: str
    alpha: float
    seed: int
    sampler_steps: int
    variant: int                       # index into spec.overrides
    run: RunConfig

    def coords(self) -> Dict[str, Any]:
        return {"index": self.index, "strategy": self.strategy,
                "scenario": self.scenario, "alpha": self.alpha,
                "seed": self.seed, "sampler_steps": self.sampler_steps,
                "variant": self.variant}


@dataclass(frozen=True)
class ExperimentSpec:
    name: str = "experiment"
    #: axes left as None inherit a single value from `base` — so a spec
    #: never silently discards e.g. base.seed just because the seed axis
    #: was not swept
    strategies: Tuple[str, ...] | None = None
    scenarios: Tuple[str, ...] | None = None
    alphas: Tuple[float, ...] | None = None
    seeds: Tuple[int, ...] | None = None
    #: AIGC sampler stride (RunConfig.sampler_steps): the quality/cost dial
    #: of the diffusion dataplane. Inherits (base.sampler_steps,) like the
    #: other axes, so oracle-only specs are unaffected.
    sampler_steps: Tuple[int, ...] | None = None
    #: non-axis RunConfig fields shared by every cell (rounds, sizes, ...)
    base: RunConfig = field(default_factory=RunConfig)
    #: per-variant RunConfig overrides; accepts dicts, stored as sorted
    #: (key, value) tuples so the spec stays hashable. One empty variant
    #: by default (the base config itself).
    overrides: Tuple = ((),)

    def __post_init__(self):
        b = self.base
        axes = {"strategies": (b.strategy,), "scenarios": (b.scenario,),
                "alphas": (b.alpha,), "seeds": (b.seed,),
                "sampler_steps": (b.sampler_steps,)}
        for axis, fallback in axes.items():
            if getattr(self, axis) is None:
                object.__setattr__(self, axis, fallback)
        object.__setattr__(self, "strategies", tuple(self.strategies))
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "alphas",
                           tuple(float(a) for a in self.alphas))
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))
        object.__setattr__(self, "sampler_steps",
                           tuple(int(s) for s in self.sampler_steps))
        object.__setattr__(self, "overrides",
                           _freeze_overrides(self.overrides))
        for axis in ("strategies", "scenarios", "alphas", "seeds",
                     "sampler_steps"):
            if not getattr(self, axis):
                raise ValueError(f"axis {axis} is empty")
        # eager validation: constructing every cell runs RunConfig's
        # registry checks, so bad strategy/scenario/planner names fail
        # here — not ten minutes into a sweep
        self.expand()

    # ------------------------------------------------------------------
    @property
    def n_cells(self) -> int:
        return (len(self.strategies) * len(self.scenarios)
                * len(self.alphas) * len(self.seeds)
                * len(self.sampler_steps) * len(self.overrides))

    def expand(self) -> List[Cell]:
        """Deterministic nested expansion: strategy (slowest) > scenario >
        alpha > seed > sampler_steps > override variant (fastest)."""
        cells: List[Cell] = []
        i = 0
        for strat in self.strategies:
            for scen in self.scenarios:
                for alpha in self.alphas:
                    for seed in self.seeds:
                        for steps in self.sampler_steps:
                            for v, ov in enumerate(self.overrides):
                                run = dataclasses.replace(
                                    self.base, strategy=strat, scenario=scen,
                                    alpha=alpha, seed=seed,
                                    sampler_steps=steps, **dict(ov))
                                cells.append(Cell(i, strat, scen, alpha,
                                                  seed, steps, v, run))
                                i += 1
        return cells

    # ------------------------------------------------------------------
    def to_payload(self) -> Dict[str, Any]:
        return {
            "schema": SPEC_SCHEMA,
            "name": self.name,
            "axes": {
                "strategies": list(self.strategies),
                "scenarios": list(self.scenarios),
                "alphas": list(self.alphas),
                "seeds": list(self.seeds),
                "sampler_steps": list(self.sampler_steps),
            },
            "base": run_payload(self.base),
            "overrides": [dict(ov) for ov in self.overrides],
        }

    def to_json(self) -> str:
        """Canonical serialization: byte-identical for equal specs across
        fresh processes (sorted keys, fixed separators, scalar leaves)."""
        return json.dumps(self.to_payload(), sort_keys=True,
                          separators=(",", ":"), allow_nan=False)

    @classmethod
    def from_payload(cls, payload: Dict[str, Any]) -> "ExperimentSpec":
        if payload.get("schema") != SPEC_SCHEMA:
            raise ValueError(f"not an {SPEC_SCHEMA} payload: "
                             f"{payload.get('schema')!r}")
        axes = payload["axes"]
        return cls(name=payload["name"],
                   strategies=tuple(axes["strategies"]),
                   scenarios=tuple(axes["scenarios"]),
                   alphas=tuple(axes["alphas"]),
                   seeds=tuple(axes["seeds"]),
                   # absent in pre-axis artifacts: inherit from base
                   sampler_steps=(tuple(axes["sampler_steps"])
                                  if axes.get("sampler_steps") is not None
                                  else None),
                   base=RunConfig(**payload["base"]),
                   overrides=tuple(payload["overrides"]))

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_payload(json.loads(text))

"""Batched sweep execution: one `Sweep` drives every cell of an
`ExperimentSpec` in round-lockstep.

What is shared across cells (and why it is exact, not approximate):

* **Dataset builds** — `make_image_dataset(name, n, seed)` is a pure
  function of its arguments, so cells that agree on them get the same
  arrays from one build (a 5-strategy sweep builds its train set once, not
  five times).
* **FleetEngines** — one engine per (CNN config, local_steps, batch_size)
  model shape. The engine is stateless across `run()` calls, so sharing
  only deduplicates jit cache keys and the cached zero-pytree.
* **SUBP2-4 planning** — each round, all jax-planner cells that agree on
  (GenFVConfig, model_bits) are planned in ONE `plan_rounds_batched`
  dispatch. The planner's done-guarded vmapped loops make the batch
  bitwise-identical to per-cell planning (DESIGN.md §Batched XLA planner),
  which is what the sweep/single parity test pins. numpy-planner cells
  fall back to per-cell host planning (the pinned paper-math reference).

**Never shared: model state.** Every cell owns its runner, global model,
RNG stream, and world — a sweep is N independent experiments that happen
to be executed well, and `Sweep.run()` must (and does, see
tests/test_exp.py) reproduce per-cell `GenFVRunner.train()` bitwise.

`SweepResult` is struct-of-arrays: one `[n_cells, max_rounds]` float
tensor per RoundLog metric (NaN-padded where a cell ran fewer rounds),
with `curve()/select()/final()/to_json()/save()` and the versioned
artifact schema of `repro.exp.artifacts`.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import GenFVConfig
from repro.configs.genfv_cifar import cnn_config
from repro.core.two_scale import plan_rounds_batched
from repro.data.synthetic import make_image_dataset
from repro.exp.artifacts import load_artifact, save_artifact, schema_tag
from repro.exp.spec import Cell, ExperimentSpec
from repro.fl.fleet import FleetEngine
from repro.fl.rounds import CLIENT_LR, GenFVRunner, run_payload
from repro.obs import NULL_OBS, log_line

SWEEP_SCHEMA = schema_tag("sweep")                     # repro.exp/sweep/v1

#: RoundLog fields captured into the metric tensors.
METRIC_KEYS = ("selected", "dropped", "t_bar", "b_gen", "kappa2",
               "emd_bar", "loss", "accuracy",
               # fault-tolerance ledger (fl/faults.py; zero on clean runs)
               "late", "rejected", "stale_merged", "t_round",
               # planner diagnostics (core/planner.py)
               "bcd_iters", "planner_converged")


class _DatasetCache:
    """Exact memo of `make_image_dataset`: identical (name, n, seed) calls
    return the same arrays (read-only consumers: partitioning copies)."""

    def __init__(self):
        self._cache: Dict[tuple, tuple] = {}
        self.builds = 0
        self.hits = 0

    def __call__(self, name: str, n: int, seed: int = 0):
        key = (name, int(n), int(seed))
        if key not in self._cache:
            self._cache[key] = make_image_dataset(name, n, seed=seed)
            self.builds += 1
        else:
            self.hits += 1
        return self._cache[key]


class Sweep:
    """Executor for an `ExperimentSpec`.

    Parameters
    ----------
    spec: the grid to run.
    fl_cfg: shared GenFVConfig for every cell (scenario overlays still
        apply per cell). None keeps the runner default
        (`GenFVConfig(dirichlet_alpha=cell.alpha)`).
    generator_factory: optional `cell -> generator` hook for non-oracle
        AIGC services (examples/diffusion_aigc.py); None uses the oracle.
    obs: a `repro.obs.Obs` tracer shared by the sweep and every cell's
        runner (each runner gets a cell-tagged view, so spans land on
        per-cell Perfetto tracks). None keeps the zero-overhead null path;
        either way the executed rounds are bitwise-identical
        (tests/test_obs.py).
    """

    def __init__(self, spec: ExperimentSpec,
                 fl_cfg: GenFVConfig | None = None,
                 generator_factory: Optional[Callable[[Cell], Any]] = None,
                 verbose: bool = False, obs=None):
        self.spec = spec
        self.fl_cfg = fl_cfg
        self.generator_factory = generator_factory
        self.verbose = verbose
        self.obs = obs if obs is not None else NULL_OBS
        self._datasets = _DatasetCache()
        self._engines: Dict[tuple, FleetEngine] = {}

    # ------------------------------------------------------------------
    def _make_runner(self, cell: Cell) -> GenFVRunner:
        run = cell.run
        fl = self.fl_cfg or GenFVConfig(dirichlet_alpha=run.alpha)
        cnn = cnn_config(run.dataset, run.width_mult)
        # scenario overlays never touch local_steps/batch_size
        # (sim/scenarios.py::_CFG_OVERRIDES), so the engine key is known
        # before the runner applies them
        key = (cnn, fl.local_steps, fl.batch_size)
        engine = self._engines.get(key)
        if engine is None:
            engine = FleetEngine(cnn, fl.local_steps, fl.batch_size,
                                 lr=CLIENT_LR, max_bucket=4096)
            self._engines[key] = engine
        gen = (self.generator_factory(cell)
               if self.generator_factory is not None else None)
        return GenFVRunner(run, fl_cfg=fl, generator=gen, engine=engine,
                           dataset_fn=self._datasets,
                           obs=self.obs.tagged(cell=cell.index))

    # ------------------------------------------------------------------
    # Sweep checkpointing (ROADMAP direction 5): per-cell runner snapshots
    # plus a JSON manifest written LAST — the manifest is the commit point,
    # so a kill mid-save is detected on resume (cell cursor mismatch) rather
    # than silently resumed from torn state. Each cell file itself is
    # written atomically (repro.checkpoint).
    # ------------------------------------------------------------------
    CKPT_SCHEMA = "repro.exp/sweep-ckpt/v1"

    def _save_checkpoint(self, directory: str, runners, completed: int):
        os.makedirs(directory, exist_ok=True)
        for i, r in enumerate(runners):
            r.save_checkpoint(os.path.join(directory, f"cell_{i:04d}.npz"))
        man = {"schema": self.CKPT_SCHEMA, "spec": self.spec.to_payload(),
               "completed_rounds": int(completed), "cells": len(runners)}
        path = os.path.join(directory, "manifest.json")
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(man, f, sort_keys=True)
        os.replace(tmp, path)

    def _try_resume(self, directory: str, runners) -> int:
        """Load a previous checkpoint if one exists; returns the lockstep
        round to resume at (0 = fresh start)."""
        path = os.path.join(directory, "manifest.json")
        if not os.path.exists(path):
            return 0
        with open(path) as f:
            man = json.load(f)
        if man.get("schema") != self.CKPT_SCHEMA:
            raise ValueError(f"sweep checkpoint schema {man.get('schema')!r}"
                             f" != {self.CKPT_SCHEMA!r}")
        if man.get("spec") != self.spec.to_payload():
            raise ValueError("sweep checkpoint belongs to a different "
                             "ExperimentSpec; refusing to resume")
        if man.get("cells") != len(runners):
            raise ValueError(f"sweep checkpoint has {man.get('cells')} cells"
                             f", spec expands to {len(runners)}")
        completed = int(man["completed_rounds"])
        for i, r in enumerate(runners):
            r.load_checkpoint(os.path.join(directory, f"cell_{i:04d}.npz"))
            want = min(completed, r.run.rounds)
            if r.next_round != want:
                raise ValueError(
                    f"cell {i} checkpoint is at round {r.next_round}, "
                    f"manifest says {want} — torn checkpoint (killed "
                    "mid-save); delete the directory and restart")
        return completed

    # ------------------------------------------------------------------
    def run(self, checkpoint_dir: str | None = None,
            checkpoint_every: int = 1,
            stop_after: int | None = None) -> "SweepResult":
        """Execute the grid in round-lockstep. With `checkpoint_dir`, all
        cell state is snapshotted every `checkpoint_every` lockstep rounds
        and a later `run()` with the same directory resumes bitwise from
        the last completed round. `stop_after` limits how many lockstep
        rounds THIS call executes (tests use it to simulate a kill)."""
        cells = self.spec.expand()
        runners = [self._make_runner(c) for c in cells]
        n = len(cells)
        max_rounds = max(c.run.rounds for c in cells)
        start_round = 0
        if checkpoint_dir is not None:
            start_round = self._try_resume(checkpoint_dir, runners)
        logs: List[List] = [list(r.logs) for r in runners]
        dispatches = 0
        batched_fleets = 0
        largest_batch = 0
        executed = 0

        for t in range(start_round, max_rounds):
            if stop_after is not None and executed >= stop_after:
                break
            active = [i for i in range(n) if t < cells[i].run.rounds]
            pending = {i: runners[i].begin_round(t) for i in active}
            plans: Dict[int, Any] = {}

            # group jax-planner cells by the only things the SUBP2-4 kernel
            # reads besides the fleet: the (post-scenario) GenFVConfig,
            # model_bits and the generation service (cells with different
            # measured/assumed t0 price eq. 48 differently and cannot share
            # a dispatch). numpy-planner cells keep the host reference.
            groups: Dict[tuple, List[int]] = {}
            for i in active:
                r = runners[i]
                if r.run.planner == "jax":
                    groups.setdefault((r.cfg, r.model_bits, r.svc),
                                      []).append(i)
                else:
                    plans[i] = r.plan(pending[i])
            for key in sorted(groups, key=lambda k: groups[k][0]):
                cfg, model_bits, svc = key
                idxs = groups[key]
                with self.obs.span("sweep/plan_batched", key=len(idxs),
                                   round=t, fleets=len(idxs)):
                    batch = plan_rounds_batched(
                        cfg, [pending[i].fleet for i in idxs], model_bits,
                        batches=cfg.local_steps,
                        b_prevs=[runners[i].b_prev for i in idxs],
                        svc=svc,
                        alpha_overrides=[pending[i].alpha for i in idxs])
                dispatches += 1
                batched_fleets += len(idxs)
                largest_batch = max(largest_batch, len(idxs))
                for i, plan in zip(idxs, batch):
                    plans[i] = plan

            for i in active:
                log = runners[i].finish_round(pending[i], plans[i])
                logs[i].append(log)
                if self.verbose:
                    c = cells[i]
                    log_line(
                        self.obs, f"sweep/cell_{c.index}",
                        f"[{c.strategy}/{c.scenario}/a{c.alpha}/s{c.seed}]"
                        f" round {t:3d} sel={log.selected:2d}"
                        f" drop={log.dropped} t_bar={log.t_bar:5.2f}s"
                        f" loss={log.loss:.3f} acc={log.accuracy:.3f}",
                        force=t == c.run.rounds - 1,
                        cell=c.index, round=t)

            executed += 1
            if checkpoint_dir is not None and \
                    (t + 1) % max(checkpoint_every, 1) == 0:
                with self.obs.span("sweep/checkpoint", round=t):
                    self._save_checkpoint(checkpoint_dir, runners, t + 1)

        meta = {
            "planner_dispatches": dispatches,
            "planner_batched_fleets": batched_fleets,
            "planner_largest_batch": largest_batch,
            "dataset_builds": self._datasets.builds,
            "dataset_cache_hits": self._datasets.hits,
            "engines": len(self._engines),
            "local_steps": [int(r.cfg.local_steps) for r in runners],
        }
        if self.obs.enabled:
            # the Sweep's sharing ledger, previously visible only in the
            # result meta: batched-planner amortization + cache efficacy
            self.obs.gauge("sweep/planner_dispatches", dispatches)
            self.obs.gauge("sweep/planner_batched_fleets", batched_fleets)
            self.obs.gauge("sweep/planner_largest_batch", largest_batch)
            self.obs.gauge("sweep/dataset_builds", self._datasets.builds)
            self.obs.gauge("sweep/dataset_cache_hits", self._datasets.hits)
            self.obs.gauge("sweep/engines", len(self._engines))
            self.obs.gauge("sweep/cells", n)
        return SweepResult.build(self.spec, cells, logs, meta)


# ---------------------------------------------------------------------------
# Struct-of-arrays result.
# ---------------------------------------------------------------------------
@dataclass
class SweepResult:
    spec: ExperimentSpec
    cells: List[Dict[str, Any]]            # coords + run fields per cell
    rounds: np.ndarray                     # [n] realized rounds
    metrics: Dict[str, np.ndarray]         # key -> [n, max_rounds] float64
    meta: Dict[str, Any] = field(default_factory=dict)

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, spec: ExperimentSpec, cells: Sequence[Cell],
              logs: Sequence[Sequence], meta: Dict[str, Any]
              ) -> "SweepResult":
        n = len(cells)
        rounds = np.array([len(lg) for lg in logs], np.int64)
        width = int(rounds.max()) if n else 0
        metrics = {k: np.full((n, width), np.nan) for k in METRIC_KEYS}
        for i, lg in enumerate(logs):
            for t, log in enumerate(lg):
                for k in METRIC_KEYS:
                    metrics[k][i, t] = float(getattr(log, k))
        local_steps = meta.pop("local_steps", [None] * n)
        cell_rows = []
        for i, c in enumerate(cells):
            row = c.coords()
            row["run"] = run_payload(c.run)
            row["local_steps"] = local_steps[i]
            cell_rows.append(row)
        return cls(spec, cell_rows, rounds, metrics, dict(meta))

    # -- selection ---------------------------------------------------------
    def _match(self, **coords) -> List[int]:
        def ok(row):
            for k, v in coords.items():
                have = row[k] if k in row else row["run"].get(k)
                if have != v:
                    return False
            return True
        return [i for i, row in enumerate(self.cells) if ok(row)]

    def select(self, **coords) -> "SweepResult":
        """Subset result for the cells matching the given coordinates
        (axis names or RunConfig fields), e.g. select(scenario="rush_hour")."""
        idx = self._match(**coords)
        if not idx:
            raise KeyError(f"no cells match {coords}")
        meta = dict(self.meta)
        meta["selected_from"] = len(self.cells)
        # trim the metric columns to the subset's realized width so the
        # payload's max_rounds stays consistent with the array shape
        width = int(self.rounds[idx].max())
        return SweepResult(
            self.spec,
            [self.cells[i] for i in idx],
            self.rounds[idx],
            {k: v[idx][:, :width] for k, v in self.metrics.items()},
            meta)

    def curve(self, key: str, **coords) -> np.ndarray:
        """The [rounds] metric curve of exactly one cell."""
        idx = self._match(**coords) if coords else list(range(len(self.cells)))
        if len(idx) != 1:
            raise KeyError(f"curve({key!r}, {coords}) matches {len(idx)} "
                           f"cells; need exactly 1")
        i = idx[0]
        return self.metrics[key][i, :int(self.rounds[i])]

    def final(self, key: str) -> np.ndarray:
        """[n_cells] last-realized-round value of a metric."""
        out = np.empty(len(self.cells))
        for i, r in enumerate(self.rounds):
            out[i] = self.metrics[key][i, int(r) - 1] if r else np.nan
        return out

    # -- serialization -----------------------------------------------------
    def to_payload(self) -> Dict[str, Any]:
        def col(a):
            return [None if not np.isfinite(x) else float(x)
                    for x in np.asarray(a, np.float64).ravel()]
        # max_rounds is the metric column width by contract (from_payload
        # reshapes on it) — read it off the arrays, not off self.rounds
        width = (next(iter(self.metrics.values())).shape[1]
                 if self.cells else 0)
        return {
            "schema": SWEEP_SCHEMA,
            "spec": self.spec.to_payload(),
            "cells": self.cells,
            "rounds": [int(r) for r in self.rounds],
            "n_cells": len(self.cells),
            "max_rounds": width,
            "metrics": {k: col(v) for k, v in self.metrics.items()},
            "meta": self.meta,
        }

    def to_json(self) -> str:
        """Canonical bytes: equal results serialize identically."""
        return json.dumps(self.to_payload(), sort_keys=True,
                          separators=(",", ":"), allow_nan=False)

    def save(self, directory: str | None = None) -> str:
        """Write the versioned sweep artifact; returns the path."""
        payload = self.to_payload()
        payload.pop("schema")              # save_artifact injects the tag
        return save_artifact(self.spec.name, "sweep", payload,
                             directory=directory)

    @classmethod
    def from_payload(cls, doc: Dict[str, Any]) -> "SweepResult":
        spec = ExperimentSpec.from_payload(doc["spec"])
        rounds = np.array(doc["rounds"], np.int64)
        n, width = doc["n_cells"], doc["max_rounds"]
        metrics = {}
        for k, flat in doc["metrics"].items():
            a = np.array([np.nan if v is None else v for v in flat],
                         np.float64)
            metrics[k] = a.reshape(n, width)
        return cls(spec, doc["cells"], rounds, metrics, doc.get("meta", {}))

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        return cls.from_payload(load_artifact(path, kind="sweep"))

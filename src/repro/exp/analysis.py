"""Theorem-1 analysis as a first-class object.

`theorem1_comparison(result)` evaluates the paper's convergence upper bound
(core/convergence.py, Theorem 1) against each sweep cell's *realized* loss
curve and aggregates bound tightness per scenario — the ROADMAP's
"scenario-conditioned convergence-bound comparison", now one API call on a
`SweepResult`.

How the bound's inputs are read off a cell (honest approximations, since
the theorem's constants are not observable from training logs):

* ``h``            — the cell's realized `local_steps` (recorded by Sweep);
* ``lambda_n``     — the paper's divergence bound `EMD_n * g_n` with the
  realized per-round mean EMD of the cell and a shared gradient scale
  ``g_n`` (same convention as benchmarks/theorem1.py has always used);
* ``kappa1/kappa2``— the cell's realized mean aggregation weights;
* ``L(w*)``        — proxied by a sweep-level lower envelope: the minimum
  loss observed anywhere in the sweep minus a 5% loss-range margin (the
  optimum is strictly below anything training reached; without the margin
  the best cell's final gap is zero by construction and its tightness
  ratio diverges);
* ``Theta``        — the cell's first-round gap to that proxy.

The output rows therefore measure *tightness* (bound / realized gap) and
*validity* (fraction of rounds where the bound sits above the realized
gap), not exact constants — which is exactly what the paper's Fig.-style
bound plots communicate.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import convergence
from repro.exp.artifacts import save_artifact, schema_tag
from repro.exp.sweep import SweepResult

THEOREM1_SCHEMA = schema_tag("theorem1")           # repro.exp/theorem1/v1


def optimal_kappa2(p: convergence.ConvergenceParams, T: int, rhos, lams,
                   n_grid: int = 21) -> tuple[float, float]:
    """Grid-minimize the Theorem-1 bound over the aggregation weight kappa2
    (the eq.-4 justification: an interior optimum exists when lambda_a is
    below the fleet-average divergence). Returns (kappa2*, bound*)."""
    grid = [(k2, convergence.bound(p, T, rhos, lams, 1.0 - k2, k2))
            for k2 in np.linspace(0.0, 1.0, n_grid)]
    k2_star, b_star = min(grid, key=lambda g: g[1])
    return float(k2_star), float(b_star)


def per_scenario_markdown(rows) -> str:
    """Markdown table for per-scenario aggregate rows (the dicts produced
    by `Theorem1Report.per_scenario()` / stored in theorem1 artifacts).
    The single formatter for the repo: reports and EXPERIMENTS.md render
    through it."""
    lines = ["| scenario | cells | EMD̄ | bound(T) | realized(T) | "
             "tightness | valid |",
             "|---|---|---|---|---|---|---|"]
    for row in rows:
        lines.append(
            f"| {row['scenario']} | {row['cells']} | "
            f"{row['emd_bar']:.2f} | {row['bound_final']:.4f} | "
            f"{row['realized_final']:.4f} | {row['tightness']:.2f}x | "
            f"{row['valid_fraction'] * 100:.0f}% |")
    return "\n".join(lines)


@dataclass
class BoundRow:
    """Bound-vs-realized comparison for one sweep cell."""
    index: int
    strategy: str
    scenario: str
    alpha: float
    seed: int
    rounds: int
    h: int
    emd_bar: float                 # realized mean EMD over rounds
    kappa2: float                  # realized mean aggregation weight
    theta: float                   # first-round gap (bound's Theta)
    bound_final: float             # Theorem-1 RHS after `rounds` rounds
    realized_final: float          # realized final gap to the L* proxy
    tightness: float               # bound_final / realized_final
    valid_fraction: float          # P_t[bound_t >= realized gap_t]
    bound_curve: List[float] = field(default_factory=list)
    realized_curve: List[float] = field(default_factory=list)


@dataclass
class Theorem1Report:
    params: Dict[str, float]       # shared ConvergenceParams fields
    loss_star: float               # the sweep-level L(w*) proxy
    g_n: float
    rows: List[BoundRow]
    meta: Dict[str, object] = field(default_factory=dict)

    # ------------------------------------------------------------------
    def per_scenario(self) -> List[Dict[str, float]]:
        """Aggregate bound tightness per scenario (the ROADMAP table)."""
        out = []
        for scen in sorted({r.scenario for r in self.rows}):
            rs = [r for r in self.rows if r.scenario == scen]
            out.append({
                "scenario": scen,
                "cells": len(rs),
                "emd_bar": float(np.mean([r.emd_bar for r in rs])),
                "bound_final": float(np.mean([r.bound_final for r in rs])),
                "realized_final": float(np.mean([r.realized_final
                                                 for r in rs])),
                "tightness": float(np.mean([r.tightness for r in rs])),
                "valid_fraction": float(np.mean([r.valid_fraction
                                                 for r in rs])),
            })
        return out

    def to_markdown(self) -> str:
        return per_scenario_markdown(self.per_scenario())

    def to_payload(self) -> Dict[str, object]:
        return {
            "params": self.params,
            "loss_star": self.loss_star,
            "g_n": self.g_n,
            "rows": [dataclasses.asdict(r) for r in self.rows],
            "per_scenario": self.per_scenario(),
            "meta": self.meta,
        }

    def save(self, name: str, directory: str | None = None) -> str:
        return save_artifact(name, "theorem1", self.to_payload(),
                             directory=directory)


# ---------------------------------------------------------------------------
def theorem1_comparison(result: SweepResult,
                        params: Optional[convergence.ConvergenceParams]
                        = None,
                        g_n: float = 0.25,
                        n_ref: int = 8) -> Theorem1Report:
    """Evaluate the Theorem-1 bound against every cell's realized curve.

    `params` supplies the unobservable constants (smoothness, convexity,
    lr, lambda_a); `h` and `theta` are overridden per cell from the sweep.
    `n_ref` is the reference fleet size for the uniform rho_n weights.
    """
    base = params or convergence.ConvergenceParams(eta=0.01, varrho=10.0,
                                                   mu=0.5, lambda_a=0.08)
    loss = result.metrics["loss"]
    # L* proxy strictly below every observed loss (see module docstring)
    spread = float(np.nanmax(loss) - np.nanmin(loss))
    loss_star = float(np.nanmin(loss) - max(0.05 * spread, 1e-3))
    rhos = np.full(n_ref, 1.0 / n_ref)

    rows: List[BoundRow] = []
    for i, cell in enumerate(result.cells):
        T = int(result.rounds[i])
        if T == 0:
            continue
        realized = loss[i, :T] - loss_star
        emd_bar = float(np.nanmean(result.metrics["emd_bar"][i, :T]))
        kappa2 = float(np.nanmean(result.metrics["kappa2"][i, :T]))
        h = int(cell.get("local_steps") or base.h)
        theta = float(max(realized[0], 1e-9))
        p = dataclasses.replace(base, h=h, theta=theta)
        lams = np.full(n_ref, emd_bar * g_n)
        # bound after t = 1..T rounds vs the realized gap at round t-1
        bounds = convergence.bound_curve(p, T, rhos, lams,
                                         1.0 - kappa2, kappa2)[1:]
        realized_f = float(max(realized[-1], 1e-9))
        valid = float(np.mean(bounds + 1e-12 >= realized))
        rows.append(BoundRow(
            index=cell["index"], strategy=cell["strategy"],
            scenario=cell["scenario"], alpha=cell["alpha"],
            seed=cell["seed"], rounds=T, h=h, emd_bar=emd_bar,
            kappa2=kappa2, theta=theta,
            bound_final=float(bounds[-1]), realized_final=realized_f,
            tightness=float(bounds[-1] / realized_f),
            valid_fraction=valid,
            bound_curve=[float(b) for b in bounds],
            realized_curve=[float(r) for r in realized]))

    shared = {k: getattr(base, k)
              for k in ("beta", "varrho", "mu", "eta", "sigma", "lambda_a")}
    meta = {"n_ref": n_ref,
            "planner_dispatches": result.meta.get("planner_dispatches"),
            "planner_batched_fleets":
                result.meta.get("planner_batched_fleets")}
    return Theorem1Report(params=shared, loss_star=loss_star, g_n=g_n,
                          rows=rows, meta=meta)

"""Versioned JSON artifact store for experiment outputs.

Every artifact is a single JSON object carrying a ``schema`` tag of the
form ``repro.exp/<kind>/v<N>``; readers (`benchmarks/make_experiments_md.py`)
dispatch on it instead of guessing at ad-hoc per-figure layouts. Files are
written with sorted keys and fixed separators so that re-running a
deterministic producer rewrites the byte-identical file (clean diffs).

Default location: ``artifacts/`` under the current working directory
(benchmarks and examples run from the repo root); override per call or via
``REPRO_ARTIFACTS``.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List

SCHEMA_PREFIX = "repro.exp"


def artifact_dir(directory: str | None = None) -> str:
    d = directory or os.environ.get("REPRO_ARTIFACTS", "artifacts")
    os.makedirs(d, exist_ok=True)
    return d


def schema_tag(kind: str, version: int = 1) -> str:
    return f"{SCHEMA_PREFIX}/{kind}/v{version}"


def _sanitize(obj):
    """JSON-safe copy: numpy scalars -> python, NaN/inf -> None."""
    import numpy as np
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [_sanitize(v) for v in obj.tolist()]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        obj = float(obj)
    if isinstance(obj, float):
        return obj if obj == obj and abs(obj) != float("inf") else None
    return obj


def save_artifact(name: str, kind: str, payload: Dict[str, Any],
                  directory: str | None = None, version: int = 1) -> str:
    """Write ``<dir>/<name>.<kind>.json`` with the schema tag injected.
    Returns the path."""
    doc = {"schema": schema_tag(kind, version)}
    doc.update(_sanitize(payload))
    path = os.path.join(artifact_dir(directory), f"{name}.{kind}.json")
    with open(path, "w") as f:
        json.dump(doc, f, sort_keys=True, indent=1, allow_nan=False)
        f.write("\n")
    return path


def load_artifact(path: str, kind: str | None = None) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    tag = doc.get("schema", "")
    if not tag.startswith(SCHEMA_PREFIX + "/"):
        raise ValueError(f"{path}: not a {SCHEMA_PREFIX} artifact ({tag!r})")
    if kind is not None and tag.split("/")[1] != kind:
        raise ValueError(f"{path}: expected kind {kind!r}, got {tag!r}")
    return doc


def list_artifacts(kind: str, directory: str | None = None) -> List[str]:
    d = directory or os.environ.get("REPRO_ARTIFACTS", "artifacts")
    return sorted(glob.glob(os.path.join(d, f"*.{kind}.json")))

"""repro.exp — composable experiment API for the paper's sweep-shaped
results (Figs. 5-10, Theorem 1).

spec       ExperimentSpec: a declarative grid over strategy x scenario x
           alpha x seed x config-override variants; `expand()` freezes one
           validated RunConfig per cell. `grid()` is the bare ordered
           cartesian product for non-FL parameter loops.
sweep      Sweep: executes a spec sharing dataset builds and FleetEngines
           across cells, and routing all jax-planner cells' SUBP2-4
           through batched `plan_rounds_batched` dispatches. Returns a
           struct-of-arrays SweepResult (round x cell metric tensors with
           curve/select/to_json/save and a versioned artifact schema).
analysis   Theorem-1 as an API call: evaluate the convergence bound per
           cell against its realized loss curve and aggregate
           bound-tightness per scenario.
artifacts  versioned JSON artifact store (default: artifacts/).
"""
from repro.exp.analysis import Theorem1Report, optimal_kappa2, \
    per_scenario_markdown, theorem1_comparison
from repro.exp.artifacts import artifact_dir, list_artifacts, \
    load_artifact, save_artifact
from repro.exp.spec import SPEC_SCHEMA, Cell, ExperimentSpec, grid
from repro.exp.sweep import SWEEP_SCHEMA, Sweep, SweepResult

__all__ = [
    "Cell", "ExperimentSpec", "grid", "SPEC_SCHEMA",
    "Sweep", "SweepResult", "SWEEP_SCHEMA",
    "Theorem1Report", "theorem1_comparison", "optimal_kappa2",
    "per_scenario_markdown",
    "artifact_dir", "save_artifact", "load_artifact", "list_artifacts",
]

"""Scenario sweep: the same GenFV pipeline under different traffic worlds.

  PYTHONPATH=src python examples/scenario_sweep.py [--rounds N] [--scenarios a,b]

One `repro.exp` experiment: the scenario axis of an `ExperimentSpec`
enumerates the registered traffic presets (repro/sim/scenarios.py), and
`Sweep` runs every cell sharing one dataset build and FleetEngine, with
all cells' SUBP2-4 planning batched per round. The summary table shows how
traffic shapes federated learning: rush-hour jams keep vehicles in
coverage for many rounds (stable fleets, few dropouts), free-flow highways
churn the fleet, sparse cells starve selection.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl import RunConfig
from repro.sim import scenario_names


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--scenarios", default="",
                    help="comma-separated subset (default: all registered)")
    ap.add_argument("--save", action="store_true",
                    help="write the artifacts/scenario_sweep.sweep.json "
                         "artifact")
    args = ap.parse_args()
    names = tuple([s for s in args.scenarios.split(",") if s]
                  or scenario_names())

    spec = ExperimentSpec(
        name="scenario_sweep",
        scenarios=names,
        base=RunConfig(rounds=args.rounds, train_size=600, test_size=64,
                       width_mult=0.125))
    result = Sweep(spec, fl_cfg=GenFVConfig(batch_size=16, local_steps=2,
                                            num_vehicles=10)).run()
    if args.save:
        print(f"artifact: {result.save()}")

    print(f"{len(names)} scenarios, "
          f"{result.meta['planner_dispatches']} batched planner dispatches "
          f"(largest batch {result.meta['planner_largest_batch']}), "
          f"{result.meta['dataset_builds']} dataset builds for "
          f"{spec.n_cells} cells")
    print(f"\n{'scenario':<20} {'sel/round':>9} {'dropped':>8} "
          f"{'t_bar':>7} {'emd_bar':>8} {'final acc':>10}")
    for name in names:
        sub = result.select(scenario=name)
        print(f"{name:<20} "
              f"{float(sub.curve('selected', scenario=name).mean()):>9.1f} "
              f"{int(sub.curve('dropped', scenario=name).sum()):>8d} "
              f"{float(sub.curve('t_bar', scenario=name).mean()):>7.2f} "
              f"{float(sub.curve('emd_bar', scenario=name).mean()):>8.2f} "
              f"{float(sub.final('accuracy')[0]):>10.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

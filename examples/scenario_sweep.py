"""Scenario sweep: the same GenFV pipeline under different traffic worlds.

  PYTHONPATH=src python examples/scenario_sweep.py [--rounds N] [--scenarios a,b]

Each named scenario (repro/sim/scenarios.py) parameterizes the persistent
vehicular world — arrival rate, speed law, coverage geometry, shadowing —
and the same selection/allocation/augmentation stack runs on top. The
summary table shows how traffic shapes federated learning: rush-hour jams
keep vehicles in coverage for many rounds (stable fleets, few dropouts),
free-flow highways churn the fleet, sparse cells starve selection.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import GenFVConfig
from repro.fl import GenFVRunner, RunConfig
from repro.sim import scenario_names


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--scenarios", default="",
                    help="comma-separated subset (default: all registered)")
    args = ap.parse_args()
    names = ([s for s in args.scenarios.split(",") if s]
             or list(scenario_names()))

    rows = []
    for name in names:
        runner = GenFVRunner(
            RunConfig(rounds=args.rounds, train_size=600, test_size=64,
                      width_mult=0.125, scenario=name),
            fl_cfg=GenFVConfig(batch_size=16, local_steps=2, num_vehicles=10))
        res = runner.train()
        rows.append((name,
                     float(res.curve("selected").mean()),
                     int(res.curve("dropped").sum()),
                     float(res.curve("t_bar").mean()),
                     float(res.curve("emd_bar").mean()),
                     float(res.logs[-1].accuracy)))
        print(f"[{name}] done: acc={rows[-1][-1]:.3f}")

    print(f"\n{'scenario':<20} {'sel/round':>9} {'dropped':>8} "
          f"{'t_bar':>7} {'emd_bar':>8} {'final acc':>10}")
    for name, sel, drop, t_bar, emd, acc in rows:
        print(f"{name:<20} {sel:>9.1f} {drop:>8d} {t_bar:>7.2f} "
              f"{emd:>8.2f} {acc:>10.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

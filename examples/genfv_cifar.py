"""End-to-end GenFV driver (paper Sec. VI): federated training of the
ResNet-18-style CNN on the CIFAR10-like procedural dataset with Dirichlet
non-IID partitions, comparing GenFV against FL-only and FedAvg.

  PYTHONPATH=src python examples/genfv_cifar.py [--rounds 12] [--alpha 0.1]

This is the "train a ~100M-model-class workload for a few hundred steps"
driver at CPU scale: 12 rounds x 16 vehicles x 4 local steps = ~768 SGD
steps through the federated pipeline.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl import RunConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--dataset", default="cifar10")
    ap.add_argument("--schemes", default="genfv,fl_only,fedavg")
    ap.add_argument("--scenario", default="highway_free_flow",
                    help="repro.sim traffic scenario, or 'legacy' for the "
                         "memoryless per-round fleet sampler")
    args = ap.parse_args()

    # one declarative grid over the scheme axis; Sweep shares the dataset
    # build across schemes and plans all their rounds in batched dispatches
    spec = ExperimentSpec(
        name="genfv_cifar",
        strategies=tuple(args.schemes.split(",")),
        alphas=(args.alpha,),
        base=RunConfig(dataset=args.dataset, rounds=args.rounds,
                       train_size=2000, test_size=192, width_mult=0.125,
                       seed=3, model_bits=11.2e6 * 32,
                       scenario=args.scenario))
    fl_cfg = GenFVConfig(batch_size=16, local_steps=4, num_vehicles=16)
    result = Sweep(spec, fl_cfg=fl_cfg, verbose=True).run()

    print("\n=== summary (mean of last 3 rounds) ===")
    for scheme in spec.strategies:
        acc = result.curve("accuracy", strategy=scheme)
        print(f"  {scheme:10s} acc={np.mean(acc[-3:]):.3f}  "
              f"curve={[round(a, 3) for a in acc.tolist()]}")


if __name__ == "__main__":
    main()

"""End-to-end GenFV driver (paper Sec. VI): federated training of the
ResNet-18-style CNN on the CIFAR10-like procedural dataset with Dirichlet
non-IID partitions, comparing GenFV against FL-only and FedAvg.

  PYTHONPATH=src python examples/genfv_cifar.py [--rounds 12] [--alpha 0.1]

This is the "train a ~100M-model-class workload for a few hundred steps"
driver at CPU scale: 12 rounds x 16 vehicles x 4 local steps = ~768 SGD
steps through the federated pipeline.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.base import GenFVConfig
from repro.fl import GenFVRunner, RunConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--dataset", default="cifar10")
    ap.add_argument("--schemes", default="genfv,fl_only,fedavg")
    ap.add_argument("--scenario", default="highway_free_flow",
                    help="repro.sim traffic scenario, or 'legacy' for the "
                         "memoryless per-round fleet sampler")
    args = ap.parse_args()

    fl_cfg = GenFVConfig(batch_size=16, local_steps=4, num_vehicles=16)
    results = {}
    for scheme in args.schemes.split(","):
        print(f"\n=== {scheme} (alpha={args.alpha}) ===")
        runner = GenFVRunner(
            RunConfig(dataset=args.dataset, alpha=args.alpha,
                      rounds=args.rounds, strategy=scheme, train_size=2000,
                      test_size=192, width_mult=0.125, seed=3,
                      model_bits=11.2e6 * 32, scenario=args.scenario),
            fl_cfg=fl_cfg)
        res = runner.train(verbose=True)
        results[scheme] = res.curve("accuracy")

    print("\n=== summary (mean of last 3 rounds) ===")
    for scheme, acc in results.items():
        print(f"  {scheme:10s} acc={np.mean(acc[-3:]):.3f}  "
              f"curve={[round(a, 3) for a in acc.tolist()]}")


if __name__ == "__main__":
    main()

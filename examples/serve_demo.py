"""Batched serving demo: prefill a batch of prompts, then decode with the
ring-buffer KV cache — the same serve_step the decode_32k / long_500k
dry-run shapes lower, at CPU scale. Includes a sliding-window arch so the
ring buffer actually wraps.

  PYTHONPATH=src python examples/serve_demo.py [--arch gemma2-9b]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)

    max_len = P + args.gen
    cache = api.init_cache(cfg, B, max_len)
    prefill = jax.jit(api.make_prefill_step(cfg))
    decode = jax.jit(api.make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, cache, {"tokens": prompts})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"[serve] {args.arch} (reduced): prefill {B}x{P} tokens "
          f"in {t_prefill * 1e3:.1f} ms")

    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    t0 = time.time()
    for t in range(P, P + args.gen - 1):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = decode(params, cache, outs[-1], pos)
        outs.append(jnp.argmax(logits, -1)[:, None])
    jax.block_until_ready(logits)
    dt = (time.time() - t0) / max(args.gen - 1, 1)
    print(f"[serve] decoded {args.gen} tokens/seq, {dt * 1e3:.1f} ms/token "
          f"(batch {B})")
    gen = jnp.concatenate(outs, axis=1)
    for i in range(B):
        print(f"  seq{i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()

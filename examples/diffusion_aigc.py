"""AIGC dataplane demo (paper Sec. III-B): the real diffusion service
behind ``RunConfig(generator="ddpm")`` — pretrained class-conditional DDPM,
one bucketed sampling dispatch per round, measured per-image latency priced
into eq. 48's schedule, and ``sampler_steps`` as a sweep axis.

  PYTHONPATH=src python examples/diffusion_aigc.py [--rounds 2]

The first run pretrains the reference-pool generator (cached under
--ckpt-dir afterwards) and calibrates t0 into artifacts/gen_calib.json;
reruns restore both.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import numpy as np

from repro.configs.base import GenFVConfig
from repro.exp import ExperimentSpec, Sweep
from repro.fl.rounds import RunConfig
from repro.gen import (calibrated_service, gen_round_key, pretrain_ddpm,
                       runner_ddpm, sample_schedule)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="artifacts")
    args = ap.parse_args()

    # 1. the RSU foundation model: pretrain (or restore) the generator the
    #    runner itself serves, on the same budget, checkpointed via
    #    repro.checkpoint
    ddpm = runner_ddpm(num_classes=10)
    params, losses = pretrain_ddpm(
        ddpm, ckpt_path=os.path.join(args.ckpt_dir, "ddpm_demo"))
    if losses:
        print(f"[pretrain] {len(losses)} steps, "
              f"final loss {losses[-1]:.4f}")
    else:
        print("[pretrain] restored from checkpoint")

    # 2. sample one round schedule directly: round-keyed stream, bucketed
    #    batched dispatch (the exact path the server takes)
    imgs = sample_schedule(params, ddpm, gen_round_key(seed=0, round_idx=0),
                           labels=np.arange(10) % 10, sampler_steps=10)
    print(f"[sample] {imgs.shape} in [-1,1]: min={imgs.min():.2f} "
          f"max={imgs.max():.2f}")

    # 3. measured per-image cost -> eq. 12-13 delay terms (cached in
    #    artifacts/gen_calib.json; the runner does this implicitly)
    svc = calibrated_service(params, ddpm, sampler_steps=10)
    print(f"[calib] t0 = {svc.t_per_image * 1e3:.1f} ms/image "
          f"({svc.source}, steps={svc.steps})")

    # 4. the round loop end to end: generator="ddpm" swaps the oracle for
    #    this service, and sampler_steps is a first-class sweep axis — the
    #    SUBP4 quality/cost dial
    print("\n[genfv] sampler_steps sweep with the DDPM as the AIGC service")
    spec = ExperimentSpec(
        name="diffusion_aigc",
        sampler_steps=(10, 50),
        base=RunConfig(generator="ddpm", rounds=args.rounds, train_size=600,
                       test_size=64, width_mult=0.125))
    result = Sweep(spec,
                   fl_cfg=GenFVConfig(batch_size=16, local_steps=2,
                                      num_vehicles=8),
                   verbose=True).run()
    for i, cell in enumerate(result.cells):
        print(f"[genfv+ddpm] steps={cell['sampler_steps']:3d} "
              f"final accuracy {float(result.final('accuracy')[i]):.3f} "
              f"b_gen total {int(np.nansum(result.metrics['b_gen'][i]))}")


if __name__ == "__main__":
    main()

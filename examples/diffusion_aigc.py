"""AIGC service demo (paper Sec. III-B): train the class-conditional DDPM on
a reference pool, then plug it into the GenFV server as the generator —
the full diffusion path instead of the fast oracle.

  PYTHONPATH=src python examples/diffusion_aigc.py [--train-steps 150]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GenFVConfig
from repro.data.synthetic import make_image_dataset
from repro.diffusion import DDPM, ddpm_loss, ddpm_sample, make_ddpm
from repro.exp import ExperimentSpec, Sweep
from repro.fl.generator import DDPMGenerator
from repro.fl.rounds import RunConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--rounds", type=int, default=2)
    args = ap.parse_args()

    ddpm = DDPM(timesteps=50, num_classes=10, base_width=16)
    params = make_ddpm(jax.random.PRNGKey(0), ddpm)
    imgs, labels = make_image_dataset("cifar10", 512, seed=0, noise=0.15)
    imgs, labels = jnp.asarray(imgs), jnp.asarray(labels)

    @jax.jit
    def step(p, k, bi, bl):
        loss, g = jax.value_and_grad(ddpm_loss, argnums=0)(p, ddpm, k, bi, bl)
        return jax.tree.map(lambda w, gg: w - 2e-4 * gg, p, g), loss

    rng = np.random.default_rng(0)
    k = jax.random.PRNGKey(1)
    t0 = time.time()
    for s in range(args.train_steps):
        ix = rng.integers(0, len(labels), 32)
        k, ks = jax.random.split(k)
        params, loss = step(params, ks, imgs[ix], labels[ix])
        if s % 25 == 0 or s == args.train_steps - 1:
            print(f"[ddpm] step {s:4d} loss {float(loss):.4f} "
                  f"({(time.time() - t0):.0f}s)")

    samples = ddpm_sample(params, ddpm, jax.random.PRNGKey(2),
                          np.arange(10) % 10)
    print(f"[ddpm] sampled {samples.shape} in [-1,1]: "
          f"min={float(samples.min()):.2f} max={float(samples.max()):.2f}")

    print("\n[genfv] running rounds with the trained DDPM as the AIGC service")
    # a one-cell repro.exp experiment; generator_factory plugs the trained
    # DDPM in as each cell's AIGC service instead of the fast oracle
    spec = ExperimentSpec(
        name="diffusion_aigc",
        base=RunConfig(rounds=args.rounds, train_size=600, test_size=64,
                       width_mult=0.125))
    result = Sweep(spec,
                   fl_cfg=GenFVConfig(batch_size=16, local_steps=2,
                                      num_vehicles=8),
                   generator_factory=lambda cell: DDPMGenerator(params, ddpm),
                   verbose=True).run()
    print(f"[genfv+ddpm] final accuracy {float(result.final('accuracy')[0]):.3f}")


if __name__ == "__main__":
    main()

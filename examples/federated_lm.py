"""GenFV on a language-model backbone (DESIGN.md §5: the technique is
architecture-agnostic — it consumes label/token distributions and parameter
trees, not images).

Vehicles hold non-IID token streams (each sees only a slice of the vocab —
the LM analogue of Dirichlet label skew); EMD is computed over token
unigram histograms; the RSU "generates" synthetic text from the full-vocab
reference stream (the token-level AIGC service) and trains the augmented
model; aggregation is eq. (4) verbatim.

  PYTHONPATH=src python examples/federated_lm.py [--arch qwen1.5-0.5b]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.emd import aggregate, data_weights, emd as emd_fn, kappas, mean_emd
from repro.data.synthetic import make_token_dataset
from repro.models import api
from repro.models.transformer import loss_fn
from repro.optim import make_optimizer, constant_schedule


def token_histogram(tokens, vocab, bins=16):
    h = np.bincount(np.asarray(tokens) % bins, minlength=bins).astype(float)
    return h / max(h.sum(), 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    B, S = 4, 48
    key = jax.random.PRNGKey(0)
    global_params = api.init_params(key, cfg)
    opt = make_optimizer("sgd", constant_schedule(0.3))
    step = jax.jit(api.make_train_step(cfg, opt, clip_norm=1.0))

    # non-IID client corpora: client i only sees tokens in its vocab slice
    rng = np.random.default_rng(0)
    full = make_token_dataset(cfg.vocab_size, 80_000, seed=1)
    slice_w = cfg.vocab_size // args.clients
    corpora, hists = [], []
    for i in range(args.clients):
        lo = i * slice_w
        toks = lo + (full[i::args.clients] % slice_w)
        corpora.append(toks.astype(np.int32))
        hists.append(token_histogram(toks, cfg.vocab_size))
    emds = [emd_fn(h) for h in hists]
    print(f"[federated-lm] {args.arch} (reduced), {args.clients} clients, "
          f"token-EMDs: {[round(e, 2) for e in emds]}")

    def local_train(params, corpus, steps, rng):
        state = opt.init(params)
        loss = 0.0
        for _ in range(steps):
            start = int(rng.integers(0, len(corpus) - B * (S + 1)))
            chunk = corpus[start:start + B * (S + 1)].reshape(B, S + 1)
            batch = {"tokens": jnp.asarray(chunk[:, :-1]),
                     "targets": jnp.asarray(chunk[:, 1:]),
                     "mask": jnp.ones((B, S), jnp.float32)}
            params, state, m = step(params, state, batch)
            loss = float(m["loss"])
        return params, loss

    eval_chunk = full[:B * (S + 1)].reshape(B, S + 1)
    eval_batch = {"tokens": jnp.asarray(eval_chunk[:, :-1]),
                  "targets": jnp.asarray(eval_chunk[:, 1:]),
                  "mask": jnp.ones((B, S), jnp.float32)}
    eval_loss = jax.jit(lambda p: loss_fn(p, cfg, eval_batch)[0])

    for t in range(args.rounds):
        models, sizes = [], []
        for i, corpus in enumerate(corpora):
            m, _ = local_train(global_params, corpus, args.local_steps, rng)
            models.append(m)
            sizes.append(len(corpus))
        # token-level AIGC: the RSU samples from the reference distribution
        aug, _ = local_train(global_params, full, args.local_steps, rng)
        emd_bar = mean_emd(emds)
        global_params = aggregate(models, data_weights(sizes), aug, emd_bar)
        k1, k2 = kappas(emd_bar)
        print(f"  round {t}: global-eval loss {float(eval_loss(global_params)):.4f} "
              f"(kappa2={k2:.3f})")
    print("[federated-lm] done — eq. (4) applied unchanged to an LM pytree")


if __name__ == "__main__":
    main()

"""Train any assigned architecture end-to-end on the synthetic token stream
(reduced config, CPU-runnable), exercising the same train_step the dry-run
lowers for the production mesh.

  PYTHONPATH=src python examples/train_backbone.py --arch olmoe-1b-7b --steps 30
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    raise SystemExit(main())

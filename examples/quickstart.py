"""Quickstart: the three layers of the framework in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. paper math  — EMD weighting + the two-scale resource allocator
2. model zoo   — one assigned backbone, forward + decode
3. experiments — a 2-cell repro.exp grid, two GenFV rounds end-to-end
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

# ---- 1. the paper's control plane -----------------------------------------
from repro.configs.base import GenFVConfig
from repro.core import mobility, plan_round
from repro.core.emd import kappas

cfg = GenFVConfig()
rng = np.random.default_rng(0)
hists = rng.dirichlet(np.full(10, 0.3), size=30)       # vehicle label dists
fleet = mobility.sample_fleet(rng, cfg, hists,
                              rng.integers(500, 2000, 30))
plan = plan_round(cfg, fleet, model_bits=11.2e6 * 32, batches=8)
print(f"[two-scale] selected {len(plan.selected)}/{len(fleet)} vehicles, "
      f"t_bar={plan.t_bar:.2f}s, generate b={plan.b_gen} images")
k1, k2 = kappas(float(np.mean([fleet[i].emd for i in plan.selected])))
print(f"[eq.4] aggregation weights kappa1={k1:.3f} kappa2={k2:.3f}")

# ---- 2. an assigned architecture ------------------------------------------
from repro.configs import get_config
from repro.models import api

mcfg = get_config("qwen1.5-0.5b").reduced()
params = api.init_params(jax.random.PRNGKey(0), mcfg)
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, mcfg.vocab_size)
out = api.greedy_generate(mcfg, params, prompt, steps=8)
print(f"[model] qwen1.5-0.5b (reduced) generated tokens: {out[0].tolist()}")

# ---- 3. federated experiments -----------------------------------------------
from repro.exp import ExperimentSpec, Sweep
from repro.fl import RunConfig

spec = ExperimentSpec(
    strategies=("genfv", "fl_only"),      # a 2-cell grid
    base=RunConfig(rounds=2, train_size=600, test_size=64, width_mult=0.125))
result = Sweep(spec, fl_cfg=GenFVConfig(batch_size=16, local_steps=2,
                                        num_vehicles=8), verbose=True).run()
for s in spec.strategies:
    print(f"[{s}] final accuracy "
          f"{float(result.curve('accuracy', strategy=s)[-1]):.3f}")
